"""Standalone job-store worker: ``python -m repro.serve.worker``.

Drains a durable ``JobStore`` in its own process — the multi-process
face of the serving layer.  Any number of workers (and in-process
``Executor``s) can point at one store + cache directory: claims are
lock-arbitrated, archive/manifest writes reload-merge under file locks,
and every job runs with ``resume=True``, so a worker killed mid-segment
(power loss, OOM, SIGKILL) leaves a checkpoint a successor restores —
the re-run spends only the residual budget and lands on the
bit-identical final front.

    python -m repro.serve.worker --store DIR --cache DIR [--once]
        [--poll S] [--segment-delay S] [--pop N]
        [--chunk-generations N] [--no-adaptive] [--tech PRESET]

``--once`` drains the currently-pending jobs and exits (CI / tests);
without it the worker polls forever.  The engine knobs (``--pop`` /
``--chunk-generations`` / ``--no-adaptive``) must match across the
workers of one store — the resume checkpoint's signature folds the
engine configuration in, so a mismatched successor falls back to a
fresh run instead of restoring a foreign checkpoint.
``--segment-delay`` sleeps inside every segment callback — it exists to
widen the kill window so the crash-resume e2e test can SIGKILL
deterministically mid-run.  One JSON line per finished job goes to
stdout (id, state, attempt ledger, front size)."""

from __future__ import annotations

import argparse
import json
import sys
import time

from ..explore.api import Session
from .executor import run_job
from .jobs import JobStore


def _drain(session: Session, store: JobStore, segment_delay: float) -> int:
    """Claim-and-run every currently-pending job; returns how many this
    worker actually won (other workers may steal from under us — that is
    the arbitration working, not an error)."""
    on_segment = (lambda ev: time.sleep(segment_delay)) \
        if segment_delay > 0 else None
    n = 0
    for rec in store.pending():
        claimed = store.claim(rec.job_id)
        if claimed is None:
            continue
        try:
            res = run_job(session, store, claimed, on_segment=on_segment)
        except Exception:
            res = None              # run_job already journaled FAILED
        final = store.get(rec.job_id)
        print(json.dumps(dict(
            job=rec.job_id, state=final.state if final else "?",
            attempts=final.attempts if final else None,
            n_evals_attempts=final.n_evals_attempts if final else None,
            front_size=int(len(res.front_objs)) if res is not None
            else None)), flush=True)
        n += 1
    return n


def _session(args) -> Session:
    kwargs = {}
    if args.pop or args.chunk_generations or args.no_adaptive:
        from ..explore.nsga import NSGAConfig
        from ..explore.service import BudgetPolicy
        if args.pop:
            kwargs["nsga"] = NSGAConfig(pop=args.pop, generations=2)
        kwargs["policy"] = BudgetPolicy(
            chunk_generations=args.chunk_generations or 8,
            adaptive=not args.no_adaptive)
    if args.tech:
        kwargs["tech"] = args.tech
    return Session(cache_dir=args.cache, **kwargs)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve.worker",
        description="drain a repro.serve job store")
    ap.add_argument("--store", required=True,
                    help="job store directory (one JSON file per job)")
    ap.add_argument("--cache", required=True,
                    help="shared archive cache directory")
    ap.add_argument("--once", action="store_true",
                    help="drain currently-pending jobs, then exit")
    ap.add_argument("--poll", type=float, default=0.2,
                    help="idle poll interval in seconds")
    ap.add_argument("--pop", type=int, default=0,
                    help="NSGA population override")
    ap.add_argument("--chunk-generations", type=int, default=0,
                    help="BudgetPolicy.chunk_generations override")
    ap.add_argument("--no-adaptive", action="store_true",
                    help="disable plateau early-stopping")
    ap.add_argument("--tech", default="",
                    help="tech preset for this worker's session: a name "
                         "registered under $REPRO_CALIB_DIR or a "
                         "CalibratedTech JSON artifact path (default: "
                         "the uncalibrated constants; per-query tech "
                         "names still resolve either way)")
    ap.add_argument("--segment-delay", type=float, default=0.0,
                    help="sleep this long in every segment callback "
                         "(test hook: widens the crash window)")
    args = ap.parse_args(argv)

    store = JobStore(args.store)
    session = _session(args)
    for rec in store.recover():
        print(json.dumps(dict(job=rec.job_id, state="RECOVERED",
                              attempts=rec.attempts)), flush=True)
    while True:
        n = _drain(session, store, args.segment_delay)
        if args.once:
            return 0
        if n == 0:
            time.sleep(args.poll)


if __name__ == "__main__":
    sys.exit(main())
