"""Durable job records for the async serving layer.

A *job* is one ``Query`` a client handed to ``Session.submit_async``:
the problem (serialized well enough to rebuild a bit-identical
``Problem``), the search options, a deterministic PRNG seed, and a state
machine (``PENDING → RUNNING → DONE | FAILED | CANCELLED``).  Jobs live
as one JSON file each under ``<store>/job-<id>.json`` — the *job
journal* — written atomically (tmp + ``os.replace``), so the store is
readable after any crash and a restarted worker can ``recover()`` the
jobs a dead process left RUNNING and run them to completion.  Combined
with the engine's per-segment checkpoint (``run_queries(resume=True)``),
a SIGKILLed job resumes from its last completed scan segment and spends
only the residual budget.

Claiming is lock-arbitrated (``claim`` takes the store-wide file lock),
so many worker processes can drain one store without double-running a
job; ownership is the claimer's PID, and ``recover()`` uses PID
liveness to tell a crashed owner from a busy one.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
import uuid
import warnings
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from ..core.workload import Edge, TensorRef, Workload, WorkloadGraph
from ..explore.locks import file_lock

PENDING = "PENDING"
RUNNING = "RUNNING"
DONE = "DONE"
FAILED = "FAILED"
CANCELLED = "CANCELLED"
TERMINAL = (DONE, FAILED, CANCELLED)


# ---------------------------------------------------------------------------
# problem (de)serialization — enough to rebuild a bit-identical Problem
# ---------------------------------------------------------------------------
def graph_to_json(graph: WorkloadGraph) -> Dict:
    """A ``WorkloadGraph`` as plain JSON: the frozen dataclasses are
    flat (ints, strings, tuples), so a field dump round-trips exactly —
    and exact round-trip is the contract: the rebuilt graph must produce
    the same ``Problem.key()`` or the job would refine a stranger's
    archive."""
    return dict(
        workloads=[dict(
            name=w.name, loops=[[n, b] for n, b in w.loops],
            flops_per_instance=w.flops_per_instance,
            tensors=[dict(name=t.name,
                          dims=[list(g) for g in t.dims],
                          is_output=t.is_output) for t in w.tensors])
            for w in graph.workloads],
        edges=[dict(src=e.src, dst=e.dst, tensor_src=e.tensor_src,
                    tensor_dst=e.tensor_dst) for e in graph.edges])


def graph_from_json(d: Dict) -> WorkloadGraph:
    return WorkloadGraph(
        workloads=[Workload(
            name=w["name"],
            loops=tuple((n, int(b)) for n, b in w["loops"]),
            tensors=tuple(TensorRef(t["name"],
                                    tuple(tuple(g) for g in t["dims"]),
                                    t["is_output"])
                          for t in w["tensors"]),
            flops_per_instance=w["flops_per_instance"])
            for w in d["workloads"]],
        edges=[Edge(e["src"], e["dst"], e["tensor_src"],
                    e["tensor_dst"]) for e in d["edges"]])


@dataclasses.dataclass
class JobRecord:
    """One durable job.  ``payload`` is the serialized query (graph,
    objectives, space bounds, budget, engine options); ``seed`` fixes
    the PRNG chain so every attempt — first run, crash resume, cross-
    process reconstruction — draws identical keys.  ``attempts`` counts
    claims; ``n_evals_attempts`` the evaluations each attempt actually
    spent (the resume-overhead ledger: a perfect resume's attempts sum
    to the uninterrupted run's spend)."""
    job_id: str
    state: str
    payload: Dict
    problem_key: str                # Problem.key() — the job-journal key
    cache_key: str                  # tech-folded archive identity; the
    #                                 worker asserts its session derives
    #                                 the same one (tech mismatch = the
    #                                 wrong archive entirely)
    seed: int
    created_t: float
    updated_t: float = 0.0
    owner_pid: Optional[int] = None
    attempts: int = 0
    n_evals_attempts: List[int] = dataclasses.field(default_factory=list)
    elapsed_attempts: List[float] = dataclasses.field(default_factory=list)
    error: Optional[str] = None

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: Dict) -> "JobRecord":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


def _pid_alive(pid: Optional[int]) -> bool:
    if pid is None:
        return False
    try:
        os.kill(int(pid), 0)
        return True
    except ProcessLookupError:
        return False
    except PermissionError:         # exists, owned by someone else
        return True
    except OSError:
        return False


class JobStore:
    """The on-disk job journal: one atomically-written JSON file per
    job under ``root``, plus a store-wide file lock arbitrating claims.

    Every read is from disk (job files are small and the store is the
    cross-process source of truth); every write goes through tmp +
    ``os.replace``.  ``claim`` is the only compound operation: under the
    lock it re-reads the record, verifies it is still claimable, and
    flips it to RUNNING owned by this PID — two workers draining one
    store can never both win a job."""

    def __init__(self, root):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._lock = self.root / "store.lock"

    # ---- paths ----------------------------------------------------------
    def _path(self, job_id: str) -> Path:
        return self.root / f"job-{job_id}.json"

    # ---- CRUD -----------------------------------------------------------
    def create(self, payload: Dict, problem_key: str, cache_key: str,
               seed: int) -> JobRecord:
        rec = JobRecord(
            job_id=uuid.uuid4().hex[:12], state=PENDING, payload=payload,
            problem_key=problem_key, cache_key=cache_key, seed=int(seed),
            created_t=time.time(), updated_t=time.time())
        self._write(rec)
        return rec

    def get(self, job_id: str) -> Optional[JobRecord]:
        p = self._path(job_id)
        try:
            return JobRecord.from_json(json.loads(p.read_text()))
        except FileNotFoundError:
            return None
        except Exception as e:      # a torn record is unreachable, not
            warnings.warn(f"unreadable job record {p}: {e}")    # fatal
            return None

    def _write(self, rec: JobRecord) -> None:
        rec.updated_t = time.time()
        p = self._path(rec.job_id)
        tmp = p.with_name(f".{p.name}.tmp{os.getpid()}")
        try:
            tmp.write_text(json.dumps(rec.to_json()))
            os.replace(tmp, p)
        finally:
            tmp.unlink(missing_ok=True)

    def update(self, rec: JobRecord, **fields) -> JobRecord:
        for k, v in fields.items():
            setattr(rec, k, v)
        self._write(rec)
        return rec

    def jobs(self) -> List[JobRecord]:
        out = []
        for p in sorted(self.root.glob("job-*.json")):
            rec = self.get(p.stem[len("job-"):])
            if rec is not None:
                out.append(rec)
        return out

    def pending(self) -> List[JobRecord]:
        """Claimable jobs, oldest first (FIFO admission)."""
        return sorted((r for r in self.jobs() if r.state == PENDING),
                      key=lambda r: r.created_t)

    # ---- the compound ops (lock-arbitrated) -----------------------------
    def claim(self, job_id: str) -> Optional[JobRecord]:
        """Atomically take ownership of one PENDING job: under the store
        lock, re-read, verify claimable, flip to RUNNING owned by this
        PID.  ``None`` when someone else won (or the job advanced)."""
        with file_lock(self._lock):
            rec = self.get(job_id)
            if rec is None or rec.state != PENDING:
                return None
            rec.state = RUNNING
            rec.owner_pid = os.getpid()
            rec.attempts += 1
            self._write(rec)
            return rec

    def recover(self) -> Tuple[JobRecord, ...]:
        """Flip RUNNING jobs whose owner PID is dead back to PENDING —
        the crash-recovery sweep a (re)starting worker runs before
        draining.  The engine checkpoint those jobs left behind makes
        the re-run a resume, not a restart."""
        recovered = []
        with file_lock(self._lock):
            for rec in self.jobs():
                if rec.state == RUNNING and not _pid_alive(rec.owner_pid):
                    rec.state = PENDING
                    rec.owner_pid = None
                    self._write(rec)
                    recovered.append(rec)
        return tuple(recovered)


__all__ = ["CANCELLED", "DONE", "FAILED", "JobRecord", "JobStore",
           "PENDING", "RUNNING", "TERMINAL", "graph_from_json",
           "graph_to_json"]
