"""`repro.explore` service benchmark: front quality (hypervolume vs. the
Fig.-9 random-sampling baseline from ``bench_pareto``), cached-vs-cold
query throughput, and adaptive-vs-fixed budget spending.

Acceptance gates reported as derived values:

* ``hv_ratio`` — hypervolume of the service's latency-cost front over the
  hypervolume of N random samples (N = the ``bench_pareto`` budget;
  512 QUICK / 2048 full).  Must be >= 1.
* ``speedup`` — cold query wall-time over the *identical* warm query
  (served from the on-disk archive).  Must be >= 5.
* ``adaptive`` — hypervolume-plateau early stopping must reach >= 99% of
  the fixed-budget run's final archive hypervolume while spending <= 70%
  of its evaluations.  Both runs use the same PRNG key and the same
  segmented spending (``BudgetPolicy.chunk_generations``), so the
  adaptive trajectory is an exact prefix of the fixed one — the gate
  measures purely where the plateau detector cuts.

Timings are always measured live (never read from the artifact cache);
the archive files for the benchmarked problems are deleted up front so
first queries are genuinely cold.
"""

from __future__ import annotations

import threading
import time

import jax
import numpy as np

import repro.core as C
from repro.explore.archive import hypervolume_2d, pareto_front
from repro.explore.nsga import NSGAConfig
from repro.explore.service import BudgetPolicy, ExplorationService

from . import bench_pareto
from .common import ARTIFACTS, QUICK, cached

OBJECTIVES = ("latency_ns", "cost_usd")
SPACE_KW = dict(max_shape=(32, 32, 4, 4, 2, 2))     # = bench_pareto's space


# the adaptive-vs-fixed arm runs a *bounded* exploration problem (single
# chiplet, 4x4 PE / 2x2 core ceiling) whose front the NSGA search can
# actually exhaust inside the benchmark budget — the motivating scenario
# for plateau early-stopping: a fixed-budget service keeps re-evaluating
# long after the front stopped moving, the adaptive one banks the tail.
# Restricting the variation fields + dropping random immigrants makes the
# run converge (immigrants exist precisely to keep injecting diversity,
# i.e. to prevent the plateau this arm must demonstrate) and keeps the
# scan-body compile small.
ADAPT_SPACE_KW = dict(max_shape=(4, 4, 2, 2, 1, 1))
ADAPT_NSGA = NSGAConfig(pop=32, immigrants=0.0, mutations=1,
                        fields=("shape", "spatial", "order", "tiling"))


def _adaptive_arm(graph, budget, adaptive):
    """One cold run of the bounded problem under the default plateau knobs
    (adaptive) or with early stopping disabled (fixed).  Identical PRNG
    key + identical segmenting => the adaptive trajectory is an exact
    prefix of the fixed one; the gate measures where the detector cuts."""
    mode = "adaptive" if adaptive else "fixed"
    svc = ExplorationService(
        cache_dir=ARTIFACTS / f"explore_cache_{mode}", nsga=ADAPT_NSGA,
        policy=BudgetPolicy(adaptive=adaptive, reallocate=False))
    spec = C.SystemSpec.build(graph, ch_max=1)
    space = C.DesignSpace(spec, **ADAPT_SPACE_KW)
    stale = svc._path(svc.problem_key(spec, space))
    if stale.exists():
        stale.unlink()                           # both runs must be cold
    t0 = time.perf_counter()
    res = svc.explore(graph, OBJECTIVES, budget=budget, ch_max=1,
                      space_kwargs=ADAPT_SPACE_KW,
                      key=jax.random.PRNGKey(42))
    return res, time.perf_counter() - t0


def _adaptive_rows(fixed, t_fixed, adapt, t_adapt):
    # archive-projected log-space hypervolume after the last segment —
    # the exact quantity the plateau detector monitors
    hv_fixed = float(fixed.trace.archive_hv[-1, 0])
    hv_adapt = float(adapt.trace.archive_hv[-1, 0])
    hv_frac = hv_adapt / max(hv_fixed, 1e-12)
    ev_frac = adapt.n_evals_run / max(fixed.n_evals_run, 1)
    ok = hv_frac >= 0.99 and ev_frac <= 0.70
    return [
        {"name": "explore/adaptive_fixed_arm",
         "us_per_call": t_fixed * 1e6,
         "derived": (f"evals={fixed.n_evals_run} hv={hv_fixed:.6g} "
                     f"gens={fixed.trace.generations}")},
        {"name": "explore/adaptive_adaptive_arm",
         "us_per_call": t_adapt * 1e6,
         "derived": (f"evals={adapt.n_evals_run} hv={hv_adapt:.6g} "
                     f"gens={adapt.trace.generations} "
                     f"plateaued={adapt.plateaued} "
                     f"banked={adapt.n_evals_banked}")},
        {"name": "explore/adaptive_gate", "us_per_call": 0,
         "derived": (f"hv_frac={hv_frac:.4f} evals_frac={ev_frac:.2f} "
                     f"({'PASS' if ok else 'FAIL'} >=0.99 & <=0.70)")},
    ]


def run(quick: bool = True):
    graph = C.presets.transformer_block()
    spec = C.SystemSpec.build(graph, ch_max=4)
    space = C.DesignSpace(spec, **SPACE_KW)

    # the fixed arm of the adaptive gate runs on a background thread: its
    # (small) scan-body compile overlaps the transformer arm's (big) one,
    # keeping the QUICK wall clock at the seed benchmark's level
    adapt_graph = C.presets.bert_mms()["att2"]
    adapt_budget = 4096 if QUICK else 8192
    fixed_box = {}

    def _fixed_job():
        try:
            fixed_box["res"], fixed_box["t"] = _adaptive_arm(
                adapt_graph, adapt_budget, adaptive=False)
        except BaseException as e:           # surfaced after join()
            fixed_box["err"] = e

    fixed_thread = threading.Thread(target=_fixed_job)
    fixed_thread.start()

    n = 512 if QUICK else 2048
    # the random-sampling baseline IS bench_pareto's Fig.-9 point cloud —
    # shared via the same artifact cache (and the same spec/space above);
    # a stale artifact from a different QUICK setting is regenerated so
    # the hv comparison is n-vs-n
    t0 = time.perf_counter()
    data = cached("fig9_pareto", bench_pareto.compute)
    if not 0.9 * n <= len(data["points"]) <= n:     # compute() drops a few
        #                                             non-finite samples
        data = cached("fig9_pareto", bench_pareto.compute, refresh=True)
    rand_pts = np.asarray([[p["latency_ns"], p["cost_usd"]]
                           for p in data["points"]], np.float64)
    rand_pts = rand_pts[np.all(np.isfinite(rand_pts), axis=1)]
    t_rand = time.perf_counter() - t0
    ref = rand_pts.max(axis=0) * 1.1
    hv_rand = hypervolume_2d(rand_pts, ref)

    svc = ExplorationService(cache_dir=ARTIFACTS / "explore_cache",
                             nsga=NSGAConfig(pop=64))
    stale = svc._path(svc.problem_key(spec, space))
    if stale.exists():
        stale.unlink()                       # guarantee a cold first query

    t0 = time.perf_counter()
    cold = svc.explore(graph, OBJECTIVES, budget=n, ch_max=4,
                       space_kwargs=SPACE_KW)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm = svc.explore(graph, OBJECTIVES, budget=n, ch_max=4,
                       space_kwargs=SPACE_KW)
    t_warm = time.perf_counter() - t0

    hv_cold = hypervolume_2d(cold.front_objs, ref)
    hv_ratio = hv_cold / max(hv_rand, 1e-12)
    speedup = t_cold / max(t_warm, 1e-9)
    assert not cold.from_cache and warm.from_cache
    np.testing.assert_allclose(cold.front_objs, warm.front_objs)

    fixed_thread.join()
    if "err" in fixed_box:
        raise fixed_box["err"]
    adapt, t_adapt = _adaptive_arm(adapt_graph, adapt_budget, adaptive=True)
    adaptive_rows = _adaptive_rows(fixed_box["res"], fixed_box["t"],
                                   adapt, t_adapt)

    return adaptive_rows + [
        {"name": "explore/hv_random", "us_per_call": t_rand * 1e6,
         "derived": (f"hv={hv_rand:.4g} n={len(rand_pts)} "
                     f"front={len(pareto_front(rand_pts))}pts")},
        {"name": "explore/hv_front", "us_per_call": t_cold * 1e6,
         "derived": (f"hv={hv_cold:.4g} budget={n} "
                     f"front={len(cold.front_objs)}pts")},
        {"name": "explore/hv_ratio", "us_per_call": 0,
         "derived": (f"{hv_ratio:.3f}x vs random "
                     f"({'PASS' if hv_ratio >= 1.0 else 'FAIL'} >=1)")},
        {"name": "explore/query_cold", "us_per_call": t_cold * 1e6,
         "derived": f"evals={cold.n_evals_run}"},
        {"name": "explore/query_warm", "us_per_call": t_warm * 1e6,
         "derived": (f"speedup={speedup:.0f}x "
                     f"({'PASS' if speedup >= 5.0 else 'FAIL'} >=5x)")},
    ]
