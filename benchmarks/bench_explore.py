"""`repro.explore` service benchmark: front quality (hypervolume vs. the
Fig.-9 random-sampling baseline from ``bench_pareto``) and cached-vs-cold
query throughput.

Acceptance gates reported as derived values:

* ``hv_ratio`` — hypervolume of the service's latency-cost front over the
  hypervolume of N random samples (N = the ``bench_pareto`` budget;
  512 QUICK / 2048 full).  Must be >= 1.
* ``speedup`` — cold query wall-time over the *identical* warm query
  (served from the on-disk archive).  Must be >= 5.

Timings are always measured live (never read from the artifact cache);
the archive file for the benchmarked problem is deleted up front so the
first query is genuinely cold.
"""

from __future__ import annotations

import time

import jax
import numpy as np

import repro.core as C
from repro.explore.archive import hypervolume_2d, pareto_front
from repro.explore.nsga import NSGAConfig
from repro.explore.service import ExplorationService

from . import bench_pareto
from .common import ARTIFACTS, QUICK, cached

OBJECTIVES = ("latency_ns", "cost_usd")
SPACE_KW = dict(max_shape=(32, 32, 4, 4, 2, 2))     # = bench_pareto's space


def run(quick: bool = True):
    graph = C.presets.transformer_block()
    spec = C.SystemSpec.build(graph, ch_max=4)
    space = C.DesignSpace(spec, **SPACE_KW)

    n = 512 if QUICK else 2048
    # the random-sampling baseline IS bench_pareto's Fig.-9 point cloud —
    # shared via the same artifact cache (and the same spec/space above);
    # a stale artifact from a different QUICK setting is regenerated so
    # the hv comparison is n-vs-n
    t0 = time.perf_counter()
    data = cached("fig9_pareto", bench_pareto.compute)
    if not 0.9 * n <= len(data["points"]) <= n:     # compute() drops a few
        #                                             non-finite samples
        data = cached("fig9_pareto", bench_pareto.compute, refresh=True)
    rand_pts = np.asarray([[p["latency_ns"], p["cost_usd"]]
                           for p in data["points"]], np.float64)
    rand_pts = rand_pts[np.all(np.isfinite(rand_pts), axis=1)]
    t_rand = time.perf_counter() - t0
    ref = rand_pts.max(axis=0) * 1.1
    hv_rand = hypervolume_2d(rand_pts, ref)

    svc = ExplorationService(cache_dir=ARTIFACTS / "explore_cache",
                             nsga=NSGAConfig(pop=64))
    stale = svc._path(svc.problem_key(spec, space))
    if stale.exists():
        stale.unlink()                       # guarantee a cold first query

    t0 = time.perf_counter()
    cold = svc.explore(graph, OBJECTIVES, budget=n, ch_max=4,
                       space_kwargs=SPACE_KW)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm = svc.explore(graph, OBJECTIVES, budget=n, ch_max=4,
                       space_kwargs=SPACE_KW)
    t_warm = time.perf_counter() - t0

    hv_cold = hypervolume_2d(cold.front_objs, ref)
    hv_ratio = hv_cold / max(hv_rand, 1e-12)
    speedup = t_cold / max(t_warm, 1e-9)
    assert not cold.from_cache and warm.from_cache
    np.testing.assert_allclose(cold.front_objs, warm.front_objs)

    return [
        {"name": "explore/hv_random", "us_per_call": t_rand * 1e6,
         "derived": (f"hv={hv_rand:.4g} n={len(rand_pts)} "
                     f"front={len(pareto_front(rand_pts))}pts")},
        {"name": "explore/hv_front", "us_per_call": t_cold * 1e6,
         "derived": (f"hv={hv_cold:.4g} budget={n} "
                     f"front={len(cold.front_objs)}pts")},
        {"name": "explore/hv_ratio", "us_per_call": 0,
         "derived": (f"{hv_ratio:.3f}x vs random "
                     f"({'PASS' if hv_ratio >= 1.0 else 'FAIL'} >=1)")},
        {"name": "explore/query_cold", "us_per_call": t_cold * 1e6,
         "derived": f"evals={cold.n_evals_run}"},
        {"name": "explore/query_warm", "us_per_call": t_warm * 1e6,
         "derived": (f"speedup={speedup:.0f}x "
                     f"({'PASS' if speedup >= 5.0 else 'FAIL'} >=5x)")},
    ]
