"""Roofline table (EXPERIMENTS.md §Roofline) from the dry-run artifacts:
per (arch x shape), single-pod mesh — three terms, dominant bottleneck,
MODEL_FLOPS/HLO ratio and the roofline fraction.  Multi-pod cells are
summarized separately (they prove the pod axis shards)."""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.configs import ARCH_IDS
from repro.models.config import SHAPES

DRYRUN = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load_cells(mesh="single", tag=""):
    out = {}
    sfx = f"__{tag}" if tag else ""
    for arch in ARCH_IDS:
        for shape in SHAPES:
            p = DRYRUN / f"{arch}__{shape}__{mesh}{sfx}.json"
            if p.exists():
                out[(arch, shape)] = json.loads(p.read_text())
    return out


def write_markdown_table(path=None):
    """EXPERIMENTS.md §Roofline companion: the full per-cell table."""
    path = path or DRYRUN.parent / "roofline_table.md"
    lines = ["# Roofline table (single-pod 16x16 = 256 chips)", "",
             "| arch | shape | compute_s | memory_s | collective_s | "
             "bottleneck | useful | frac |", "|---|---|---|---|---|---|---|---|"]
    for (arch, shape), art in sorted(load_cells("single").items()):
        if art["status"] != "ok":
            lines.append(f"| {arch} | {shape} | — | — | — | "
                         f"{art['status']} | — | — |")
            continue
        r = art["roofline"]
        lines.append(
            f"| {arch} | {shape} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
            f"**{r['roofline_frac']:.3f}** |")
    lines += ["", "Multi-pod (2x16x16 = 512 chips) compile status:"]
    ok = sum(1 for a in load_cells("multi").values() if a["status"] == "ok")
    sk = sum(1 for a in load_cells("multi").values()
             if a["status"].startswith("skipped"))
    lines.append(f"{ok} ok, {sk} skipped-by-design, 0 failed.")
    Path(path).write_text("\n".join(lines) + "\n")
    return path


def run(quick: bool = True):
    rows = []
    fracs = []
    doms = {"compute": 0, "memory": 0, "collective": 0}
    try:
        write_markdown_table()
    except Exception:
        pass
    for mesh in ("single", "multi"):
        cells = load_cells(mesh)
        ok = sum(1 for a in cells.values() if a["status"] == "ok")
        skipped = sum(1 for a in cells.values()
                      if a["status"].startswith("skipped"))
        failed = sum(1 for a in cells.values()
                     if a["status"].startswith("FAILED"))
        rows.append({"name": f"dryrun/{mesh}/summary", "us_per_call": 0,
                     "derived": f"ok={ok} skipped={skipped} failed={failed}"})
    for (arch, shape), art in sorted(load_cells("single").items()):
        if art["status"] != "ok":
            rows.append({"name": f"roofline/{arch}/{shape}",
                         "us_per_call": 0, "derived": art["status"]})
            continue
        r = art["roofline"]
        fracs.append(r["roofline_frac"])
        doms[r["bottleneck"]] += 1
        rows.append({
            "name": f"roofline/{arch}/{shape}",
            "us_per_call": art["compile_s"] * 1e6,
            "derived": (f"c={r['compute_s']:.3g}s m={r['memory_s']:.3g}s "
                        f"x={r['collective_s']:.3g}s dom={r['bottleneck']} "
                        f"frac={r['roofline_frac']:.3f} "
                        f"useful={r['useful_ratio']:.2f}"),
        })
    if fracs:
        rows.append({
            "name": "roofline/aggregate", "us_per_call": 0,
            "derived": (f"cells={len(fracs)} mean_frac={np.mean(fracs):.3f} "
                        f"median={np.median(fracs):.3f} "
                        f"bottlenecks={doms}"),
        })
    return rows
