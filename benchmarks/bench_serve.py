"""``repro.serve`` serving benchmark: N concurrent clients against one
session, overload degradation, and crash-resume overhead.

Three arms, each with hard acceptance gates (asserted, not just
reported):

* ``fleet`` — N concurrent clients submit a cold/warm/refine mix of
  async jobs through one ``Executor`` (worker threads = cloned sessions
  coordinating only through the lock-arbitrated cache directory).
  Reports p50/p99 time-to-front and the cache hit rate; gates on every
  client receiving a front within the deadline.
* ``overload`` — an executor with ZERO admission slots: every warm
  query must be answered immediately with the freshest cached
  (possibly stale) front, and the banked refinements must drain once
  capacity returns.  Gates on all clients served stale + all banked
  jobs reaching DONE.
* ``resume`` — one run interrupted at a segment boundary and resumed in
  a fresh session vs the identical uninterrupted run.  Gates on
  bit-identical final fronts and exact residual-only spend; reports the
  wall-clock resume overhead (interrupted + resumed vs uninterrupted).
"""

from __future__ import annotations

import shutil
import threading
import time

import jax
import numpy as np

import repro.core as C
from repro.api import Problem, Query, Session
from repro.explore.nsga import NSGAConfig
from repro.explore.service import BudgetPolicy, RunControl
from repro.serve import DONE, Executor

from .common import ARTIFACTS, QUICK

OBJECTIVES = ("latency_ns", "cost_usd")
SPACE_KW = dict(max_shape=(16, 16, 4, 4, 1, 2))
NSGA = NSGAConfig(pop=8, generations=2)
POLICY = BudgetPolicy(chunk_generations=1, adaptive=False,
                      reallocate=False)


def _problem(k):
    return Problem(C.WorkloadGraph([C.matmul("mm", 512, 512, k)], []),
                   objectives=OBJECTIVES, ch_max=2, space_kwargs=SPACE_KW)


def _session(cache_dir):
    return Session(cache_dir=cache_dir, nsga=NSGA, policy=POLICY)


def _fleet_arm(root, budget, n_clients, deadline_s):
    """Mixed cold/warm/refine clients through one executor."""
    sess = _session(root / "cache")
    p_warm, p_cold = _problem(64), _problem(96)
    sess.submit(Query(p_warm, budget=budget))       # pre-warm one archive
    ex = Executor(sess, store=root / "jobs", max_workers=2,
                  max_pending=max(4, n_clients))
    # round-robin mix: warm hit, refine (bigger budget), cold problem
    mix = [Query(p_warm, budget=budget),
           Query(p_warm, budget=2 * budget),
           Query(p_cold, budget=budget)]
    ttf = [None] * n_clients
    results = [None] * n_clients

    def client(i):
        t0 = time.perf_counter()
        h = ex.submit(mix[i % len(mix)], key=i, deadline_s=1.0)
        r = h.stale if h.stale is not None else h.result(deadline_s)
        ttf[i] = time.perf_counter() - t0
        results[i] = r

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join(deadline_s)
    wall = time.perf_counter() - t0
    ex.shutdown()
    served = sum(r is not None for r in results)
    assert served == n_clients, (
        f"fleet: only {served}/{n_clients} clients served a front "
        f"within {deadline_s}s")
    lat = sorted(ttf)
    hits = sum(bool(r.provenance.from_cache) for r in results)
    return dict(
        wall_s=wall, p50_s=lat[len(lat) // 2],
        p99_s=lat[min(len(lat) - 1, int(0.99 * len(lat)))],
        hit_rate=hits / n_clients,
        total_evals=sum(r.provenance.n_evals_run for r in results))


def _overload_arm(root, budget, n_clients, deadline_s):
    """Zero admission slots: warm queries degrade to stale fronts
    immediately; the banked refinements drain on resume_pending."""
    sess = _session(root / "cache")
    p = _problem(64)
    sess.submit(Query(p, budget=budget))            # warm the archive
    ex = Executor(sess, store=root / "jobs", max_workers=1,
                  max_pending=0)
    t0 = time.perf_counter()
    handles = [ex.submit(Query(p, budget=budget), key=i, deadline_s=0.0)
               for i in range(n_clients)]
    stale_t = time.perf_counter() - t0
    n_stale = sum(h.stale is not None for h in handles)
    assert n_stale == n_clients, (
        f"overload: {n_stale}/{n_clients} clients served stale — warm "
        "queries must degrade to the cached front, not queue")
    assert all(h.stale.provenance.stale
               and h.stale.provenance.n_evals_run == 0 for h in handles)
    assert stale_t < deadline_s, (
        f"overload: stale serving took {stale_t:.2f}s "
        f"(deadline {deadline_s}s)")
    # capacity returns: the banked refinements must drain to DONE
    resumed = ex.resume_pending()
    for h in resumed:
        h.result(deadline_s)
    ex.shutdown()
    states = [h.state() for h in resumed]
    assert all(s == DONE for s in states), states
    return dict(n_stale=n_stale, stale_serve_s=stale_t,
                banked_drained=len(resumed))


def _resume_arm(root, budget, deadline_s):
    """Interrupted + resumed vs uninterrupted: bit-identity, residual
    spend, wall-clock overhead."""
    q = Query(_problem(64), budget=budget)
    key = jax.random.PRNGKey(11)
    t0 = time.perf_counter()
    r_full = _session(root / "full").submit(q, key=key)
    t_full = time.perf_counter() - t0

    crash = _session(root / "crash")
    ctl = RunControl()
    seen = []

    def stop_after_two(ev):
        seen.append(ev)
        if len(seen) == 2:
            ctl.stop()

    t0 = time.perf_counter()
    r_int = crash.submit(q, key=key, resume=True, control=ctl,
                         on_segment=stop_after_two)
    t_int = time.perf_counter() - t0
    assert r_int.provenance.interrupted
    t0 = time.perf_counter()
    r_res = _session(root / "crash").submit(q, key=key, resume=True)
    t_res = time.perf_counter() - t0

    identical = int(
        r_res.front_objs.tobytes() == r_full.front_objs.tobytes()
        and r_res.front_metrics.tobytes()
        == r_full.front_metrics.tobytes())
    spend_ok = int(r_int.provenance.n_evals_run
                   + r_res.provenance.n_evals_run
                   == r_full.provenance.n_evals_run)
    assert identical, "resumed front differs from uninterrupted run"
    assert spend_ok, (
        f"resume respent budget: {r_int.provenance.n_evals_run} + "
        f"{r_res.provenance.n_evals_run} != "
        f"{r_full.provenance.n_evals_run}")
    overhead = (t_int + t_res) / max(t_full, 1e-9)
    return dict(t_full_s=t_full, t_interrupted_s=t_int, t_resumed_s=t_res,
                overhead=overhead, identical=identical, spend_ok=spend_ok)


def run(quick: bool = QUICK):
    budget = 64 if quick else 256
    n_clients = 6 if quick else 16
    deadline_s = 300.0 if quick else 900.0
    root = ARTIFACTS / "serve_bench"
    if root.exists():
        shutil.rmtree(root)

    # warmup: compile the scan runner once so no arm pays XLA lowering
    _session(root / "warmup").submit(Query(_problem(64), budget=budget))

    fleet = _fleet_arm(root / "fleet", budget, n_clients, deadline_s)
    overload = _overload_arm(root / "overload", budget, 4, deadline_s)
    resume = _resume_arm(root / "resume", budget, deadline_s)

    return [
        dict(name="serve_fleet_ttf_p50", us_per_call=fleet["p50_s"] * 1e6,
             derived=f"hit_rate={fleet['hit_rate']:.2f}"),
        dict(name="serve_fleet_ttf_p99", us_per_call=fleet["p99_s"] * 1e6,
             derived=f"clients={n_clients}"),
        dict(name="serve_fleet_wall", us_per_call=fleet["wall_s"] * 1e6,
             derived=f"evals={fleet['total_evals']}"),
        dict(name="serve_overload_stale", us_per_call=
             overload["stale_serve_s"] * 1e6,
             derived=f"stale={overload['n_stale']}"
                     f";drained={overload['banked_drained']}"),
        dict(name="serve_resume_overhead", us_per_call=0,
             derived=f"overhead={resume['overhead']:.3f}"),
        dict(name="serve_resume_identical", us_per_call=0,
             derived=f"identical={resume['identical']}"
                     f";residual_spend={resume['spend_ok']}"),
    ]
