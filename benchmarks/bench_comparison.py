"""Paper Fig. 7: Monad vs Simba [25] vs NN-Baton [28] on res[2-5]
(ResNet-50 convs) + att[1-4] (BERT-large matmuls), iso-PE-budget,
EDP objective; results normalized to Simba per workload.

Paper claims: Monad averages 16% EDP reduction vs Simba and 30% vs
NN-Baton (8% / 20.8% energy).  We report our reproduction's numbers next
to those targets; see EXPERIMENTS.md for the discussion."""

from __future__ import annotations

import jax
import numpy as np

import repro.core as C
from repro.core.optimizer import SAConfig, optimize

from .common import QUICK, cached

PE_BUDGET = 4096


def _optimize_framework(name, spec, key, sa_steps, n_init, n_iter):
    bl = C.make_baseline(name, spec, key, pe_budget=PE_BUDGET)
    if name == "monad":
        # the co-design space is a superset of both baselines' spaces, so
        # warm-start one search from each baseline configuration and keep
        # the better result — the optimizer must never end up worse than a
        # point it can represent
        res = None
        for seed_name in ("simba", "nn-baton"):
            init = C.make_baseline(seed_name, spec, key,
                                   pe_budget=PE_BUDGET).init
            r = optimize(spec, bl.space, key, weights=C.OBJ_EDP,
                         bo_fields=bl.bo_fields, sa_fields=bl.sa_fields,
                         n_init=max(n_init // 2, 2),
                         n_iter=max(n_iter // 2, 3),
                         sa=SAConfig(steps=sa_steps, chains=4),
                         init_design=init)
            if res is None or r.objective < res.objective:
                res = r
    else:
        res = optimize(spec, bl.space, key, weights=C.OBJ_EDP,
                       bo_fields=bl.bo_fields, sa_fields=bl.sa_fields,
                       n_init=n_init, n_iter=n_iter,
                       sa=SAConfig(steps=sa_steps, chains=4),
                       init_design=bl.init)
    m = res.metrics
    return {"latency_ns": float(m["latency_ns"]),
            "energy_pj": float(m["energy_pj"]),
            "edp": float(m["edp"]),
            "energy_compute_pj": float(m["energy_compute_pj"]),
            "energy_network_pj": float(m["energy_network_pj"]),
            "utilization": float(m["utilization"])}


def compute():
    suite = C.presets.fig7_suite()
    sa_steps = 300 if QUICK else 500
    n_init, n_iter = (6, 12) if QUICK else (8, 24)
    out = {}
    for wi, (wname, graph) in enumerate(suite.items()):
        spec = C.SystemSpec.build(graph, ch_max=36)
        row = {}
        for fw in ("simba", "nn-baton", "monad"):
            key = jax.random.PRNGKey(hash((wname, fw)) % 2**31)
            row[fw] = _optimize_framework(fw, spec, key, sa_steps,
                                          n_init, n_iter)
        out[wname] = row
    return out


def run(quick: bool = True):
    data = cached("fig7_comparison", compute)
    rows = []
    edp_vs_simba, edp_vs_baton = [], []
    en_vs_simba, en_vs_baton = [], []
    for wname, r in data.items():
        s, b, m = r["simba"], r["nn-baton"], r["monad"]
        edp_vs_simba.append(1 - m["edp"] / s["edp"])
        edp_vs_baton.append(1 - m["edp"] / b["edp"])
        en_vs_simba.append(1 - m["energy_pj"] / s["energy_pj"])
        en_vs_baton.append(1 - m["energy_pj"] / b["energy_pj"])
        rows.append({
            "name": f"fig7/{wname}",
            "us_per_call": 0,
            "derived": (f"EDP simba={1.0:.2f} "
                        f"baton={b['edp']/s['edp']:.2f} "
                        f"monad={m['edp']/s['edp']:.2f} "
                        f"(lat {m['latency_ns']/s['latency_ns']:.2f} "
                        f"en {m['energy_pj']/s['energy_pj']:.2f})"),
        })
    rows.append({
        "name": "fig7/mean",
        "us_per_call": 0,
        "derived": (f"monad EDP reduction: vs simba "
                    f"{np.mean(edp_vs_simba)*100:.0f}% (paper 16%), "
                    f"vs nn-baton {np.mean(edp_vs_baton)*100:.0f}% "
                    f"(paper 30%); energy {np.mean(en_vs_simba)*100:.0f}%/"
                    f"{np.mean(en_vs_baton)*100:.0f}% (paper 8%/20.8%)"),
    })
    return rows
