"""Shared benchmark plumbing: timing, JSON artifact cache, CSV rows."""

from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import Callable, Dict, List

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "bench"
# QUICK=1 forces the CI-style smoke budgets even when REPRO_BENCH_FULL=1;
# by default quick mode is on unless REPRO_BENCH_FULL=1 opts into the big
# search budgets.
QUICK = (os.environ.get("QUICK") == "1"
         or os.environ.get("REPRO_BENCH_FULL", "0") != "1")


def timed(fn: Callable, *args, repeat: int = 3, **kw):
    """(result, us_per_call) — min over repeats after one warmup."""
    fn(*args, **kw)
    best = float("inf")
    out = None
    for _ in range(repeat):
        t = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t)
    return out, best * 1e6


def cached(name: str, compute: Callable[[], Dict], refresh: bool = False):
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    p = ARTIFACTS / f"{name}.json"
    if p.exists() and not refresh:
        return json.loads(p.read_text())
    out = compute()
    p.write_text(json.dumps(out, indent=1, default=float))
    return out


def emit(rows: List[Dict]):
    """Print the required ``name,us_per_call,derived`` CSV rows."""
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', 0):.1f},"
              f"{r.get('derived', '')}")
