"""Device-saturating search benchmark: megabatched distinct-problem
throughput, island-sharded scan scaling, and the tiled dominance kernel.

Four arms, each with hard acceptance gates (asserted, not just
reported):

* ``megabatch`` — ``make_nsga_fused`` dispatches with every lane a
  DISTINCT problem vs every lane the SAME problem (identical statics,
  so identical compiled code — the only difference is the stacked spec
  arrays).  Gates on distinct-problem throughput >= 0.8x the
  same-problem fused batch: fusing different problems must not cost
  more than a whisker over the embarrassing case.
* ``islands`` — the 1-device island mesh vs the plain scan: gates on
  BIT-IDENTICAL outputs (the shard_map wrapper must be free when there
  is nothing to shard), and reports single-device evals/sec.
* ``islands_multi`` — a subprocess with ``XLA_FLAGS=
  --xla_force_host_platform_device_count=N`` runs the same search
  sharded over N islands; reports evals/sec vs the 1-device arm.  On a
  CPU host the forced devices share the same cores, so the gate is
  sanity (the sharded dispatch completes and clears a floor), not
  linear speedup.
* ``pareto_kernel`` — the Pallas dominance-count kernel (interpret
  mode off-TPU) vs the fused-jnp oracle on randomized populations with
  injected duplicate rows: gates on exact count equality.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap
import time
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp

import repro.core as C
from repro.core.encoding import random_design
from repro.explore.nsga import (ISLAND_AXIS, NSGAConfig, make_nsga,
                                make_nsga_fused)
from repro.kernels.pareto_rank.ref import dominance_counts_ref

from .common import QUICK

OBJECTIVES = ("latency_ns", "cost_usd")
SPACE_KW = dict(max_shape=(16, 16, 4, 4, 1, 2))
SRC = str(Path(__file__).resolve().parents[1] / "src")


def _problem(name):
    g = C.presets.bert_mms()[name]
    spec = C.SystemSpec.build(g, ch_max=2)
    return spec, C.DesignSpace(spec, **SPACE_KW)


def _pop0(space, pop, key):
    return jax.vmap(lambda k: random_design(k, space))(
        jax.random.split(key, pop))


def _time_dispatches(fn, repeat):
    """Min wall seconds per call over ``repeat`` post-warmup calls."""
    jax.block_until_ready(fn(0))            # compile
    best = float("inf")
    for i in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(i + 1))
        best = min(best, time.perf_counter() - t0)
    return best


def _megabatch_arm(cfg, lanes, repeat):
    names = ["att1", "att2", "att3", "att4"]
    probs = [_problem(names[i % len(names)]) for i in range(lanes)]
    spec0, space0 = probs[0]
    run = make_nsga_fused(spec0, space0, OBJECTIVES, cfg, lanes=lanes)
    pops = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[_pop0(space0, cfg.pop, jax.random.PRNGKey(100 + i))
          for i in range(lanes)])
    # keys built OUTSIDE the timed region: host-side PRNGKey construction
    # is identical for both arms and would only add noise to ms-scale
    # dispatches
    keys = [jax.random.PRNGKey(j) for j in range(lanes)]
    same = [spec0.arrays] * lanes
    distinct = [p[0].arrays for p in probs]
    repeat = max(repeat, 8)         # ms-scale dispatches: min-of-few is
    #                                 too noisy for a throughput gate
    t_same = _time_dispatches(lambda i: run(keys, pops, same), repeat)
    t_distinct = _time_dispatches(
        lambda i: run(keys, pops, distinct), repeat)
    evals = lanes * cfg.pop * cfg.generations
    thr_same, thr_distinct = evals / t_same, evals / t_distinct
    ratio = thr_distinct / thr_same
    assert ratio >= 0.8, (
        f"megabatched DISTINCT problems reached only {ratio:.2f}x the "
        f"fused same-problem batch throughput (gate: >= 0.8x)")
    return dict(thr_same=thr_same, thr_distinct=thr_distinct, ratio=ratio)


def _island_arm(cfg, repeat):
    spec, space = _problem("att2")
    key, pop0 = jax.random.PRNGKey(0), _pop0(space, cfg.pop,
                                             jax.random.PRNGKey(1))
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), (ISLAND_AXIS,))
    plain = make_nsga(spec, space, OBJECTIVES, cfg)
    isl = make_nsga(spec, space, OBJECTIVES, cfg, mesh=mesh)
    a, b = plain(key, pop0), isl(key, pop0)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert np.array_equal(np.asarray(x), np.asarray(y)), (
            "1-device island mesh is NOT bit-identical to the plain scan")
    t = _time_dispatches(
        lambda i: isl(jax.random.PRNGKey(i), pop0), repeat)
    return dict(evals_per_s=cfg.pop * cfg.generations / t)


def _multi_island_arm(cfg, n_dev, repeat):
    """Evals/sec of the sharded scan in a subprocess with ``n_dev``
    forced host devices."""
    prog = textwrap.dedent(f"""
        import time
        import numpy as np, jax
        import repro.core as C
        from repro.core.encoding import random_design
        from repro.explore.nsga import ISLAND_AXIS, NSGAConfig, make_nsga
        g = C.presets.bert_mms()["att2"]
        spec = C.SystemSpec.build(g, ch_max=2)
        space = C.DesignSpace(spec, max_shape={SPACE_KW['max_shape']!r})
        assert len(jax.devices()) == {n_dev}
        mesh = jax.sharding.Mesh(np.array(jax.devices()), (ISLAND_AXIS,))
        cfg = NSGAConfig(pop={cfg.pop}, generations={cfg.generations},
                         migration_interval=2)
        pop0 = jax.vmap(lambda k: random_design(k, space))(
            jax.random.split(jax.random.PRNGKey(1), cfg.pop))
        run = make_nsga(spec, space, {OBJECTIVES!r}, cfg, mesh=mesh)
        jax.block_until_ready(run(jax.random.PRNGKey(0), pop0))
        best = float("inf")
        for i in range({repeat}):
            t0 = time.perf_counter()
            jax.block_until_ready(run(jax.random.PRNGKey(i + 1), pop0))
            best = min(best, time.perf_counter() - t0)
        print("EVALS_PER_S", cfg.pop * cfg.generations / best)
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={n_dev}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", prog], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    eps = float(r.stdout.split("EVALS_PER_S")[1].strip().split()[0])
    assert eps > 0
    return dict(evals_per_s=eps, n_dev=n_dev)


def _pareto_kernel_arm(n, k, repeat):
    os.environ["REPRO_PALLAS_INTERPRET"] = "1"
    try:
        from repro.kernels.pareto_rank.ops import dominance_counts
        ks = jax.random.split(jax.random.PRNGKey(17), 2)
        objs = jax.random.normal(ks[0], (n, k))
        objs = objs.at[n // 2:n // 2 + 8].set(objs[:8])     # exact ties
        valid = jax.random.bernoulli(ks[1], 0.8, (n,))
        got = dominance_counts(objs, valid)
        ref = dominance_counts_ref(objs, valid)
        assert np.array_equal(np.asarray(got), np.asarray(ref)), (
            "pareto_rank kernel counts diverge from the jnp oracle")
        best = float("inf")
        for _ in range(repeat):
            t0 = time.perf_counter()
            jax.block_until_ready(dominance_counts(objs, valid))
            best = min(best, time.perf_counter() - t0)
        return dict(us=best * 1e6, n=n)
    finally:
        os.environ.pop("REPRO_PALLAS_INTERPRET", None)


def run(quick: bool = QUICK):
    cfg = NSGAConfig(pop=8 if quick else 16,
                     generations=2 if quick else 4)
    lanes = 4 if quick else 8
    repeat = 2 if quick else 5
    n_dev = 2 if quick else 4

    mb = _megabatch_arm(cfg, lanes, repeat)
    one = _island_arm(cfg, repeat)
    multi = _multi_island_arm(cfg, n_dev, repeat)
    pk = _pareto_kernel_arm(256 if quick else 1024, 4, repeat)

    scaling = multi["evals_per_s"] / one["evals_per_s"]
    return [
        dict(name="scale_megabatch_distinct",
             us_per_call=1e6 * lanes * cfg.pop * cfg.generations
             / mb["thr_distinct"],
             derived=f"ratio_vs_same={mb['ratio']:.2f}"),
        dict(name="scale_islands_1dev",
             us_per_call=1e6 * cfg.pop * cfg.generations
             / one["evals_per_s"],
             derived="bit_identical=1"),
        dict(name=f"scale_islands_{n_dev}dev",
             us_per_call=1e6 * cfg.pop * cfg.generations
             / multi["evals_per_s"],
             derived=f"scaling_vs_1dev={scaling:.2f}"),
        dict(name="scale_pareto_kernel", us_per_call=pk["us"],
             derived=f"n={pk['n']};parity=1"),
    ]


if __name__ == "__main__":
    print("name,us_per_call,derived")
    from .common import emit
    emit(run())
