"""Paper Fig. 9: sampled design points and the cost-latency Pareto front
for a Transformer block, classified by packaging technology.  The paper's
observation: up to ~7x cost spread at the same latency level, and costly
interposers can *reduce* total cost by shrinking I/O area — cost-aware
co-design is a real tradeoff, not a post-hoc filter."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as C
from repro.core.constants import PACKAGING_NAMES
from repro.explore.archive import pareto_front

from .common import QUICK, cached


def compute():
    graph = C.presets.transformer_block()
    spec = C.SystemSpec.build(graph, ch_max=4)
    space = C.DesignSpace(spec, max_shape=(32, 32, 4, 4, 2, 2))
    ev = C.make_batch_evaluator(spec)
    n = 512 if QUICK else 2048
    keys = jax.random.split(jax.random.PRNGKey(0), n)
    ds = jax.vmap(lambda k: C.random_design(k, space))(keys)
    m = ev(ds)
    lat = np.asarray(m["latency_ns"], np.float64)
    cost = np.asarray(m["cost_usd"], np.float64)
    pkg = np.asarray(ds["packaging"])
    util = np.asarray(m["utilization"], np.float64)
    ok = np.isfinite(lat) & np.isfinite(cost) & (util > 0)
    pts = [{"latency_ns": float(l), "cost_usd": float(c),
            "packaging": PACKAGING_NAMES[int(p)]}
           for l, c, p in zip(lat[ok], cost[ok], pkg[ok])]
    return {"points": pts}


def _pareto(points):
    """(latency, cost) rows of the nondominated subset, sorted by latency —
    dominance itself delegates to the canonical ``repro.explore.archive``
    implementation."""
    pts = [(p["latency_ns"], p["cost_usd"]) for p in points]
    return sorted(pts[i] for i in pareto_front(pts))


def run(quick: bool = True):
    data = cached("fig9_pareto", compute)
    pts = data["points"]
    rows = []
    front = _pareto(pts)
    # cost spread at iso-latency deciles
    lats = np.array([p["latency_ns"] for p in pts])
    costs = np.array([p["cost_usd"] for p in pts])
    spreads = []
    for qlo in np.linspace(0.05, 0.85, 9):
        lo, hi = np.quantile(lats, [qlo, qlo + 0.1])
        sel = (lats >= lo) & (lats < hi)
        if sel.sum() >= 5:
            spreads.append(costs[sel].max() / costs[sel].min())
    rows.append({"name": "fig9/points", "us_per_call": 0,
                 "derived": f"n={len(pts)} pareto_size={len(front)}"})
    rows.append({"name": "fig9/cost_spread", "us_per_call": 0,
                 "derived": (f"max iso-latency cost spread="
                             f"{max(spreads):.1f}x (paper: up to 7x)")})
    by_pkg = {}
    for p in pts:
        by_pkg.setdefault(p["packaging"], []).append(p["cost_usd"])
    for k, v in by_pkg.items():
        rows.append({"name": f"fig9/median_cost/{k}", "us_per_call": 0,
                     "derived": f"{np.median(v):.1f}usd n={len(v)}"})
    return rows
