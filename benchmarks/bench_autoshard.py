"""Level-B benchmark: the Monad engine as autosharding advisor.

(1) sample efficiency: GP+PI Bayesian search vs exhaustive ground truth
    over the layout space (paper Sec. IV-C machinery, new domain);
(2) validation: the analytical model's per-cell collective-vs-compute
    ranking against the compiled dry-run artifacts."""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.autosharding.advisor import (ShardPlan, bo_search,
                                        exhaustive_best, predict)
from repro.configs import get_config
from repro.models.config import SHAPES

from .common import cached, timed

DRYRUN = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

CELLS = [("qwen2_72b", "train_4k"), ("deepseek_v2_236b", "train_4k"),
         ("qwen2_72b", "decode_32k"), ("stablelm_1_6b", "train_4k"),
         ("falcon_mamba_7b", "train_4k")]


def compute():
    out = {}
    for arch, shape in CELLS:
        cfg, sc = get_config(arch), SHAPES[shape]
        (best, score, scored), us = timed(
            lambda: exhaustive_best(cfg, sc, chips=256), repeat=1)
        bp, bs, n, trace = bo_search(cfg, sc, chips=256, budget=24)
        out[f"{arch}/{shape}"] = {
            "exhaustive_step_s": score.step_s, "n_points": len(scored),
            "bo_step_s": bs.step_s, "bo_evals": n,
            "exhaustive_us": us,
            "plan": {"data": best.data, "model": best.model,
                     "microbatch": best.microbatch, "remat": best.remat,
                     "fsdp": best.fsdp, "pp": best.pipeline_stages},
        }
    return out


def run(quick: bool = True):
    data = cached("autoshard", compute)
    rows = []
    gaps = []
    for cell, r in data.items():
        gap = r["bo_step_s"] / r["exhaustive_step_s"]
        gaps.append(gap)
        p = r["plan"]
        rows.append({
            "name": f"autoshard/{cell}", "us_per_call": r["exhaustive_us"],
            "derived": (f"best(dp={p['data']},tp={p['model']},"
                        f"mb={p['microbatch']},{p['remat']},"
                        f"fsdp={p['fsdp']},pp={p['pp']}) "
                        f"step={r['exhaustive_step_s']:.3f}s; BO reaches "
                        f"{gap:.2f}x optimum in {r['bo_evals']}/"
                        f"{r['n_points']} evals"),
        })
    # validation vs dry-run: predicted vs measured collective seconds for
    # the default layout
    preds, meas = [], []
    for arch, shape in CELLS:
        p = DRYRUN / f"{arch}__{shape}__single.json"
        if not p.exists():
            continue
        art = json.loads(p.read_text())
        if art["status"] != "ok":
            continue
        cfg, sc = get_config(arch), SHAPES[shape]
        plan = ShardPlan(data=16, model=16,
                         microbatch=art["parallel"]["microbatch"],
                         remat=art["parallel"]["remat"])
        s = predict(cfg, sc, plan)
        preds.append(s.collective_s)
        meas.append(art["roofline"]["collective_s"])
    if len(preds) >= 3:
        lp, lm = np.log(np.maximum(preds, 1e-9)), np.log(
            np.maximum(meas, 1e-9))
        corr = float(np.corrcoef(lp, lm)[0, 1])
        rows.append({"name": "autoshard/validation", "us_per_call": 0,
                     "derived": (f"log-corr(pred, dryrun collective)="
                                 f"{corr:.2f} over {len(preds)} cells")})
    rows.append({"name": "autoshard/bo_gap", "us_per_call": 0,
                 "derived": f"mean BO/exhaustive step ratio="
                            f"{np.mean(gaps):.3f}"})
    return rows
