"""Paper Fig. 8: the co-design space analysis on a Transformer block.

Ladder of enabled optimizations (each = a field subset of the uniform
encoding, searched by the same engine):

    Rand  — Simba-like hardware, random mapping parameters (baseline)
    Res   — resource assignment only        (shape + tiling)
    Dfw   — dataflow only                   (spatial + order + pipe)
    Arch  — architecture = Res + Dfw
    Net   — network only                    (family + placement)
    Pkg   — packaging only
    Inte  — integration = Net + Pkg
    Co-opt— everything (Monad)

Run once optimizing latency and once energy.  Paper: Arch 6.1x lat / 3.2x
energy, Inte 1.3x / 1.2x, Co-opt 8.1x / 3.9x over Rand; co-opt beats the
best separate optimization by 24% latency / 16% energy."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as C
from repro.core.optimizer import SAConfig, optimize

from .common import QUICK, cached

LADDER = {
    "Res": ("shape", "tiling"),
    "Dfw": ("spatial", "order", "pipe"),
    "Arch": ("shape", "tiling", "spatial", "order", "pipe"),
    "Net": ("family", "placement"),
    "Pkg": ("packaging",),
    "Inte": ("family", "placement", "packaging"),
    "Co-opt": C.ALL_FIELDS,
}
BO_OWNED = {"shape", "spatial", "packaging", "family"}


def _rand_baseline(spec, metric, n=64):
    """Simba-like fixed hardware, random parameters (paper's 'Random').
    Returns (median metrics, the median design) — the ladder settings all
    start FROM that design, so each bar measures what enabling its field
    subset buys over the same random starting point."""
    bl = C.make_baseline("simba", spec, jax.random.PRNGKey(0))
    ev = C.make_batch_evaluator(spec)
    keys = jax.random.split(jax.random.PRNGKey(42), n)
    ds = jax.vmap(lambda k: C.random_design(k, bl.space))(keys)
    # freeze the Simba hardware fields, randomize the rest
    for f in ("shape", "spatial", "packaging", "family"):
        ds[f] = jax.vmap(lambda _: bl.init[f])(jnp.arange(n))
    m = ev(ds)
    vals = np.asarray(m[metric], np.float64)
    med = int(np.argsort(vals)[len(vals) // 2])
    design = jax.tree.map(lambda x: x[med], ds)
    return ({"latency_ns": float(np.asarray(m["latency_ns"])[med]),
             "energy_pj": float(np.asarray(m["energy_pj"])[med])},
            design)


MAX_SHAPE = (16, 16, 4, 4, 2, 2)       # <= 4 chiplets/workload: 5 wl x 4 = 20


def compute():
    graph = C.presets.transformer_block()
    spec = C.SystemSpec.build(graph, ch_max=4)
    sa_steps = 250 if QUICK else 600
    n_init, n_iter = (4, 6) if QUICK else (8, 20)
    out = {}
    for objname, weights in (("latency", C.OBJ_LATENCY),
                             ("energy", C.OBJ_ENERGY)):
        metric = "latency_ns" if objname == "latency" else "energy_pj"
        rand_m, rand_design = _rand_baseline(spec, metric)
        res_out = {"Rand": rand_m}
        arch_best_design = None
        for setting, fields in LADDER.items():
            # start from the SAME random design; free only `fields`
            fixed_pkg = -1 if "packaging" in fields else int(
                np.asarray(rand_design["packaging"]))
            fixed_fam = -1 if "family" in fields else int(
                np.asarray(rand_design["family"]))
            space = C.DesignSpace(spec, max_total_pes=4096,
                                  max_shape=MAX_SHAPE,
                                  fixed_packaging=fixed_pkg,
                                  fixed_family=fixed_fam)
            bo_fields = tuple(f for f in fields if f in BO_OWNED)
            sa_fields = tuple(f for f in fields if f not in BO_OWNED) \
                or tuple(fields)
            # Co-opt follows the paper's two-stage flow: the integration
            # fields open up FROM the architecture-stage optimum
            init = rand_design
            if setting == "Co-opt" and arch_best_design is not None:
                init = arch_best_design
            res = optimize(spec, space, jax.random.PRNGKey(7),
                           weights=weights, bo_fields=bo_fields,
                           sa_fields=sa_fields, n_init=n_init,
                           n_iter=n_iter,
                           sa=SAConfig(steps=sa_steps, chains=4),
                           init_design=init)
            if setting == "Arch":
                arch_best_design = res.design
            res_out[setting] = {
                "latency_ns": float(res.metrics["latency_ns"]),
                "energy_pj": float(res.metrics["energy_pj"])}
        out[objname] = res_out
    return out


def run(quick: bool = True):
    data = cached("fig8_codesign", compute)
    rows = []
    for objname, metric in (("latency", "latency_ns"),
                            ("energy", "energy_pj")):
        base = data[objname]["Rand"][metric]
        gains = {}
        for setting in list(LADDER) :
            v = data[objname][setting][metric]
            gains[setting] = base / v
            rows.append({"name": f"fig8/{objname}/{setting}",
                         "us_per_call": 0,
                         "derived": f"improvement_vs_rand={base/v:.2f}x"})
        best_sep = max(gains["Arch"], gains["Inte"])
        co = gains["Co-opt"]
        rows.append({
            "name": f"fig8/{objname}/summary",
            "us_per_call": 0,
            "derived": (f"co-opt={co:.2f}x arch={gains['Arch']:.2f}x "
                        f"inte={gains['Inte']:.2f}x; co-opt vs best "
                        f"separate: {(1-best_sep/co)*100:.0f}% better "
                        f"(paper: 24% lat / 16% energy)"),
        })
    return rows
