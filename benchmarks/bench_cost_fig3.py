"""Paper Fig. 3: fabrication cost of 3-chiplet TPU-class vs Gemmini-class
systems under organic / passive / active packaging, normalized to the
equal-capability monolithic die.  Reproduces the three qualitative claims:
large dies gain from chipletization, tiny dies don't, and interposers add
>=15% (passive) / >=30% (active) of cost."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import (PKG_ACTIVE, PKG_ORGANIC, PKG_PASSIVE,
                        monolithic_cost, package_cost)
from repro.core.constants import PACKAGING_NAMES

from .common import timed

CHIPS = {"tpu": 331.0, "gemmini": 1.1}        # die areas mm^2 (paper Sec. II)


def run(quick: bool = True):
    rows = []
    for chip, area in CHIPS.items():
        mono = float(monolithic_cost(3 * area))
        for pkg in (PKG_ORGANIC, PKG_PASSIVE, PKG_ACTIVE):
            (cost,), us = timed(
                lambda: (float(package_cost(jnp.asarray([area] * 3), pkg)),),
                repeat=1)
            rows.append({
                "name": f"cost_fig3/{chip}/{PACKAGING_NAMES[pkg]}",
                "us_per_call": us,
                "derived": f"norm_cost={cost/mono:.3f} (mono=1.0)",
            })
    return rows
