"""Kernel micro-benchmarks (CPU wall time is NOT the perf claim — the TPU
story is the dry-run roofline; this table documents the jnp fast paths and
the memory win of blocked attention vs naive)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention_blocked
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba_scan.ops import selective_scan_assoc
from repro.kernels.mamba_scan.ref import selective_scan_ref
from repro.kernels.gp_cov.ref import matern52_ref

from .common import timed


def run(quick: bool = True):
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    B, S, H, KV, D = 1, 1024, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)

    naive = jax.jit(lambda q, k, v: attention_ref(q, k, v, "causal"))
    blocked = jax.jit(
        lambda q, k, v: flash_attention_blocked(q, k, v, "causal"))
    _, us_n = timed(lambda: naive(q, k, v).block_until_ready())
    _, us_b = timed(lambda: blocked(q, k, v).block_until_ready())
    flops = 4 * B * S * S * H * D / 2
    rows.append({"name": "kernels/attention_naive_1k", "us_per_call": us_n,
                 "derived": f"{flops/us_n/1e3:.1f} MFLOP/ms"})
    rows.append({"name": "kernels/attention_blocked_1k", "us_per_call": us_b,
                 "derived": f"{flops/us_b/1e3:.1f} MFLOP/ms "
                            f"(O(S*blk) memory vs O(S^2))"})

    Bm, Sm, Di, Ds = 2, 512, 64, 16
    u = jax.random.normal(ks[3], (Bm, Sm, Di))
    dl = jax.nn.softplus(jax.random.normal(ks[4], (Bm, Sm, Di)))
    A = -jnp.exp(jax.random.normal(ks[5], (Di, Ds)) * 0.3)
    Bc = jax.random.normal(ks[6], (Bm, Sm, Ds))
    Cc = jax.random.normal(ks[7], (Bm, Sm, Ds))
    seq = jax.jit(lambda *a: selective_scan_ref(*a)[0])
    par = jax.jit(lambda *a: selective_scan_assoc(*a)[0])
    _, us_s = timed(lambda: seq(u, dl, A, Bc, Cc).block_until_ready())
    _, us_p = timed(lambda: par(u, dl, A, Bc, Cc).block_until_ready())
    rows.append({"name": "kernels/mamba_sequential_512", "us_per_call": us_s,
                 "derived": "lax.scan reference"})
    rows.append({"name": "kernels/mamba_assoc_512", "us_per_call": us_p,
                 "derived": f"associative scan, {us_s/us_p:.1f}x vs ref"})

    X = jax.random.normal(ks[0], (256, 12))
    gp = jax.jit(lambda X: matern52_ref(X, X, 0.5))
    _, us_g = timed(lambda: gp(X).block_until_ready())
    rows.append({"name": "kernels/gp_cov_256", "us_per_call": us_g,
                 "derived": "BO surrogate covariance (jnp path)"})
    return rows
