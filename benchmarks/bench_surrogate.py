"""Surrogate-gated search benchmark: on a *held-out* library graph, the
fleet-cache surrogate must buy its evaluation savings without giving up
front quality — and ``surrogate=off`` must stay bit-identical to the
historical exact path.

Scenario (``repro.core.presets.workload_library``): the service first
explores two attention-block graphs exactly (qwen2-72b, internlm2-1.8b),
accumulating archived (design encoding, workload embedding) -> metric
rows in the fleet cache.  The held-out qwen2.5-32b attention block —
never explored, and explicitly listed on the query's ``exclude`` so its
own key could never leak into training even if cached — is then searched
twice from a cold archive with the same PRNG key and pow2 segmenting
(``BudgetPolicy(adaptive=False)`` — every arm spends exactly its
schedule):

* ``exact`` — plain NSGA, fresh cache directory: the full budget ``B``.
* ``gated`` — surrogate-gated NSGA against the populated cache at
  budget ``2B`` with ``exact_frac=0.25``: the surrogate ranks each
  generation's candidate children and only a quarter get exact
  evaluations, so the run evolves TWICE the generations for half the
  exact spend — the savings are reinvested as search depth, which is
  where gating actually pays.

Gates (ASSERTED, not just printed):

* quality:  gated final archive-projected hypervolume >= 99% of exact's;
* savings:  gated exact-evaluation spend <= 50% of the exact arm's, with
  ``surrogate_hits`` accounting for every skipped candidate;
* identity: ``surrogate`` requested against an EMPTY cache falls back to
  the exact path bit-identically (same fronts, same spend, no fit).
"""

from __future__ import annotations

import shutil
import time

import jax
import numpy as np

import repro.core as C
from repro.explore.nsga import NSGAConfig
from repro.explore.service import (BudgetPolicy, ExplorationService,
                                   ExploreQuery)

from .common import ARTIFACTS, QUICK

OBJECTIVES = ("latency_ns", "cost_usd")
# bounded space (<= 2x2 core / 1x2 chiplet arrays): the budgets below can
# actually converge the front, the regime where skipped evaluations could
# plausibly cost hypervolume — the honest setting for the 99% gate
SPACE_KW = dict(max_shape=(8, 8, 2, 2, 1, 2))
CH_MAX = 2
NSGA = NSGAConfig(pop=32, immigrants=0.0, mutations=1)
POLICY = BudgetPolicy(adaptive=False, reallocate=False)
KEY = 42

TRAIN = ("attn_qwen2_72b", "attn_internlm2")
HELD_OUT = "attn_qwen2_5_32b"
SUR_OPTS = dict(exact_frac=0.25, min_rows=16, epochs=300,
                beta=1.5, tau=0.5)


def _service(tag: str, wipe: bool = True, **kw) -> ExplorationService:
    d = ARTIFACTS / f"surrogate_cache_{tag}"
    if wipe and d.exists():
        shutil.rmtree(d)                     # every arm starts cold on disk
    kw.setdefault("policy", POLICY)
    return ExplorationService(cache_dir=d, nsga=NSGA, **kw)


def _explore(svc, graph, budget, surrogate=None):
    q = ExploreQuery(graph, OBJECTIVES, budget=budget, ch_max=CH_MAX,
                     space_kwargs=SPACE_KW, surrogate=surrogate)
    t0 = time.perf_counter()
    res, = svc.run_queries([q], key=jax.random.PRNGKey(KEY))
    return res, time.perf_counter() - t0


def run(quick: bool = True):
    lib = C.presets.workload_library()
    budget = 1024 if QUICK else 4096         # pow2 x pop => exact spends
    held = lib[HELD_OUT]

    # --- exact arm: plain NSGA, fresh cache, full budget ------------------
    svc_exact = _service("exact")
    exact, t_exact = _explore(svc_exact, held, budget)
    assert not exact.from_cache and not exact.surrogate_used
    hv_exact = float(exact.trace.archive_hv[-1, 0])

    # --- gated arm: cache populated from the training graphs first -------
    svc = _service("gated")
    t_pop = 0.0
    for name in TRAIN:
        _, dt = _explore(svc, lib[name], budget)
        t_pop += dt
    spec = C.SystemSpec.build(held, ch_max=CH_MAX)
    held_key = svc.problem_key(spec, C.DesignSpace(spec, **SPACE_KW))
    gated, t_gated = _explore(
        svc, held, 2 * budget,
        surrogate=dict(SUR_OPTS, exclude=[held_key]))
    assert not gated.from_cache
    assert gated.surrogate_used, "fleet cache failed to yield a fit"
    hv_gated = float(gated.trace.archive_hv[-1, 0])

    hv_ratio = hv_gated / max(hv_exact, 1e-12)
    ev_frac = gated.n_evals_run / max(exact.n_evals_run, 1)
    # spent + skipped must reconstruct the gated arm's OWN 2B schedule
    from repro.explore import quantize
    sched = quantize.schedule(2 * budget, NSGA.pop, POLICY.chunk_generations)
    total = sched.pop * sched.chunk * sched.n_seg
    accounted = gated.n_evals_run + gated.surrogate_hits
    ok = (hv_ratio >= 0.99 and ev_frac <= 0.50 and accounted == total)
    assert ok, (f"surrogate gate failed: hv_ratio={hv_ratio:.4f} "
                f"(>=0.99), evals_frac={ev_frac:.2f} (<=0.50), "
                f"accounted={accounted} vs schedule={total}")

    # --- off-identity: surrogate on an EMPTY cache == surrogate=None ------
    svc_a = _service("ident_a")
    svc_b = _service("ident_b")
    small = budget // 4
    ra, t_ra = _explore(svc_a, held, small, surrogate=dict(SUR_OPTS))
    rb, _ = _explore(svc_b, held, small)
    ident = (not ra.surrogate_used
             and ra.n_evals_run == rb.n_evals_run
             and np.array_equal(ra.front_objs, rb.front_objs)
             and np.array_equal(ra.front_metrics, rb.front_metrics))
    assert ident, "cold-cache surrogate run diverged from the exact path"

    return [
        {"name": "surrogate/train_populate", "us_per_call": t_pop * 1e6,
         "derived": f"graphs={len(TRAIN)} budget={budget}"},
        {"name": "surrogate/exact_arm", "us_per_call": t_exact * 1e6,
         "derived": f"evals={exact.n_evals_run} hv={hv_exact:.6g}"},
        {"name": "surrogate/gated_arm", "us_per_call": t_gated * 1e6,
         "derived": (f"evals={gated.n_evals_run} hv={hv_gated:.6g} "
                     f"hits={gated.surrogate_hits} "
                     f"fallbacks={gated.surrogate_fallbacks}")},
        {"name": "surrogate/gate", "us_per_call": 0,
         "derived": (f"hv_ratio={hv_ratio:.4f} evals_frac={ev_frac:.2f} "
                     f"({'PASS' if ok else 'FAIL'} hv>=0.99 & <=0.50 "
                     f"& accounted)")},
        {"name": "surrogate/off_identity", "us_per_call": t_ra * 1e6,
         "derived": (f"bit_identical={'PASS' if ident else 'FAIL'} "
                     f"evals={ra.n_evals_run}")},
    ]
