"""Benchmark runner — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [module ...]

Emits ``name,us_per_call,derived`` CSV (benchmarks/common.emit).  Heavy
results are cached under artifacts/bench/*.json; delete a JSON (or set
REPRO_BENCH_FULL=1 for the bigger search budgets) to recompute.
"""

import sys

from . import (bench_validation, bench_cost_fig3, bench_comparison,
               bench_codesign, bench_pareto, bench_explore, bench_transfer,
               bench_obs, bench_serve, bench_tt, bench_roofline,
               bench_autoshard, bench_kernels, bench_scale,
               bench_surrogate)
from .common import QUICK, emit

MODULES = {
    "validation": bench_validation,    # Sec. V-A model-vs-simulator
    "cost_fig3": bench_cost_fig3,      # Fig. 3
    "comparison": bench_comparison,    # Fig. 7 (Simba / NN-Baton / Monad)
    "codesign": bench_codesign,        # Fig. 8 ladder
    "pareto": bench_pareto,            # Fig. 9
    "explore": bench_explore,          # repro.explore front + cache service
    "transfer": bench_transfer,        # cross-workload transfer warm-starts
    "obs": bench_obs,                  # flight-recorder overhead + journal
    "serve": bench_serve,              # async jobs, overload, crash-resume
    "tt": bench_tt,                    # Fig. 10 case study
    "roofline": bench_roofline,        # dry-run roofline table
    "autoshard": bench_autoshard,      # Level-B advisor
    "kernels": bench_kernels,          # kernel micro-table
    "scale": bench_scale,              # islands, megabatch, dominance kernel
    "surrogate": bench_surrogate,      # surrogate-gated eval savings
}


def main() -> None:
    names = sys.argv[1:] or list(MODULES)
    print("name,us_per_call,derived")
    for n in names:
        rows = MODULES[n].run(quick=QUICK)
        emit(rows)


if __name__ == "__main__":
    main()
