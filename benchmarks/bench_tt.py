"""Paper Fig. 10 case study: tensor-train contraction chain
(C23 -> C33 -> C43 -> C52) on a chiplet accelerator.

Two parts, mirroring the paper's narrative:

1. the PAPER-SCALE design point — one (small) chiplet each for the
   lower-dimensional contractions, two big chiplets each for the O(n^6)
   ones — evaluated with our models and compared against the
   equal-total-area monolithic die (paper: 28% cost cut).  Big dies are
   the Fig.-3 regime where chipletization pays.
2. the cost-aware OPTIMIZER run (OBJ_COST_EDP vs OBJ_EDP) on the same
   chain — at the sizes the optimizer picks for this workload it heads to
   the small-die regime, which is itself a Fig.-3-consistent outcome we
   report (Sec. V-D's point: cost must be in the loop, area alone cannot
   make the call)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as C
from repro.core.constants import PACKAGING_NAMES
from repro.core.cost import monolithic_cost, package_cost
from repro.core.optimizer import SAConfig, optimize
from repro.core.evaluate import evaluate_system

from .common import QUICK, cached


def paper_design(spec):
    """Fig. 10b: small chiplets for C23/C33, 2 big chiplets for C43/C52."""
    W = spec.W
    # the paper's regime: the O(n^6) contractions get two LARGE dies each
    # (its C33 chip alone exceeds 300 mm^2); with our 28nm area constants
    # the same regime is ~150-200 mm^2/die
    shape = np.array([[16, 16, 4, 4, 1, 1],     # c23: 1 small chiplet
                      [32, 32, 8, 8, 1, 1],     # c33: 1 big chiplet
                      [32, 32, 10, 10, 1, 2],   # c43: 2 large chiplets
                      [32, 32, 10, 10, 1, 2]],  # c52: 2 large chiplets
                     np.int32)
    spatial = np.zeros((W, 6), np.int32)
    spatial[:] = [0, 1, 0, 1, 0, 1]
    order = np.tile(np.arange(8, dtype=np.int32), (W, 3, 1))
    bounds = spec.arrays["bounds"]
    tiling = np.stack([np.minimum(bounds, 64),
                       np.minimum(bounds, 512)], axis=1).astype(np.int32)
    return dict(
        shape=jnp.asarray(shape), spatial=jnp.asarray(spatial),
        order=jnp.asarray(order), tiling=jnp.asarray(tiling),
        pipe=jnp.asarray([0] * W, jnp.int32),
        logB=jnp.asarray(2, jnp.int32),
        packaging=jnp.asarray(C.PKG_PASSIVE, jnp.int32),
        family=jnp.asarray(1, jnp.int32),          # ring (paper Fig. 10b)
        placement=jnp.asarray(np.arange(spec.W * spec.CH), jnp.int32),
    )


def compute():
    graph = C.presets.tt_chain(s=48, r=48)
    spec = C.SystemSpec.build(graph, ch_max=4)
    out = {}

    # --- part 1: paper-scale fixed design vs monolithic ---------------------
    d = paper_design(spec)
    m = evaluate_system(spec, d)
    area = float(m["area_mm2"])
    out["paper_design"] = {
        "latency_ns": float(m["latency_ns"]),
        "energy_pj": float(m["energy_pj"]),
        "cost_usd": float(m["cost_usd"]),
        "area_mm2": area,
        "monolithic_cost": float(monolithic_cost(area)),
        "chiplets_per_workload": [1, 1, 2, 2],
        "packaging": "passive-interposer",
    }

    # --- part 2: cost-aware vs cost-blind optimization ----------------------
    sa = SAConfig(steps=250 if QUICK else 600, chains=4)
    n_init, n_iter = (4, 6) if QUICK else (8, 16)
    for label, weights in (("edp", C.OBJ_EDP),
                           ("cost_edp", C.OBJ_COST_EDP)):
        space = C.DesignSpace(spec, max_shape=(32, 32, 8, 8, 2, 2))
        res = optimize(spec, space, jax.random.PRNGKey(3), weights=weights,
                       n_init=n_init, n_iter=n_iter, sa=sa)
        dd = res.design
        mm = res.metrics
        chips = np.asarray(dd["shape"])[:, 4] * np.asarray(dd["shape"])[:, 5]
        out[label] = {
            "latency_ns": float(mm["latency_ns"]),
            "energy_pj": float(mm["energy_pj"]),
            "cost_usd": float(mm["cost_usd"]),
            "area_mm2": float(mm["area_mm2"]),
            "chiplets_per_workload": chips.tolist(),
            "packaging": PACKAGING_NAMES[int(np.asarray(dd["packaging"]))],
            "monolithic_cost": float(monolithic_cost(float(mm["area_mm2"]))),
        }
    return out


def run(quick: bool = True):
    data = cached("fig10_tt", compute)
    rows = []
    p = data["paper_design"]
    red = 1 - p["cost_usd"] / p["monolithic_cost"]
    rows.append({
        "name": "tt_case/paper_design", "us_per_call": 0,
        "derived": (f"area={p['area_mm2']:.0f}mm2 "
                    f"cost={p['cost_usd']:.0f}usd vs mono "
                    f"{p['monolithic_cost']:.0f}usd -> cut {red*100:.0f}% "
                    f"(paper 28%) chiplets={p['chiplets_per_workload']} "
                    f"ring/passive-interposer"),
    })
    for label in ("edp", "cost_edp"):
        r = data[label]
        rows.append({
            "name": f"tt_case/opt_{label}", "us_per_call": 0,
            "derived": (f"cost={r['cost_usd']:.0f}usd "
                        f"area={r['area_mm2']:.0f}mm2 "
                        f"lat={r['latency_ns']/1e3:.0f}us "
                        f"chiplets={r['chiplets_per_workload']} "
                        f"pkg={r['packaging']}"),
        })
    ce, ee = data["cost_edp"], data["edp"]
    rows.append({
        "name": "tt_case/cost_awareness", "us_per_call": 0,
        "derived": (f"cost-aware {ce['cost_usd']:.0f}usd vs cost-blind "
                    f"{ee['cost_usd']:.0f}usd "
                    f"({ee['cost_usd']/max(ce['cost_usd'],1e-9):.2f}x)"),
    })
    return rows
