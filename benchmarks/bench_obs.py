"""``repro.obs`` flight-recorder benchmark: instrumentation overhead and
journal completeness.

Acceptance gates reported as derived values:

* ``overhead`` — wall-time of an instrumented + journaled submission over
  the identical submission with observability disabled (min over repeats,
  fresh archive each rep, scan runners pre-compiled by a warmup).  Must
  be <= 1.03 (3%), with a small absolute floor so a sub-100ms workload
  can't fail the gate on scheduler noise.
* ``identical`` — the enabled and disabled arms must produce
  bit-identical front metrics (instrumentation reads clocks, never
  numeric state).  Must be 1.
* ``replay`` — folding the journal back through ``obs.replay`` must
  reproduce the in-memory ``Result``: same segment count, same
  evaluation total, same final archive-projected hypervolume.  Must
  be 1.
* ``report`` — the rendered plan-vs-actual report must show every
  planned segment with an actual observation.  Must be 1.
"""

from __future__ import annotations

import shutil
import time

import jax

import repro.core as C
from repro import obs
from repro.api import Problem, Query, Session
from repro.explore.nsga import NSGAConfig
from repro.explore.service import BudgetPolicy
from repro.obs.report import render

from .common import ARTIFACTS, QUICK

OBJECTIVES = ("latency_ns", "cost_usd")
SPACE_KW = dict(max_shape=(16, 16, 4, 4, 1, 2))
NSGA = NSGAConfig(pop=8, generations=2)
POLICY = BudgetPolicy(chunk_generations=2, adaptive=False,
                      reallocate=False)


def _graph(k):
    return C.WorkloadGraph([C.matmul("mm", 512, 512, k)], [])


def _submit_cold(cache_dir, journal, budget):
    """One cold submission into a FRESH archive directory (the scan
    runners stay compiled in the process-wide NSGA cache, so after the
    warmup this measures pure segment execution + bookkeeping)."""
    if cache_dir.exists():
        shutil.rmtree(cache_dir)
    s = Session(cache_dir=cache_dir, journal=journal, nsga=NSGA,
                policy=POLICY)
    return s.submit(Query(Problem(_graph(64), objectives=OBJECTIVES,
                                  ch_max=2, space_kwargs=SPACE_KW),
                          budget=budget),
                    key=jax.random.PRNGKey(7))


def run(quick: bool = QUICK):
    budget = 64 if quick else 256
    repeats = 3 if quick else 5
    root = ARTIFACTS / "obs_bench"
    if root.exists():
        shutil.rmtree(root)

    # warmup compiles the scan variant both arms reuse — first-call XLA
    # lowering must not be attributed to either arm
    _submit_cold(root / "warmup", False, budget)

    # arms are INTERLEAVED (off, on, off, on, ...) with min-over-repeats
    # per arm, so page-cache warmup and scheduler drift hit both equally
    # instead of biasing whichever arm runs first
    jp = None
    best = {False: float("inf"), True: float("inf")}
    result = {False: None, True: None}
    for i in range(repeats):
        for enabled in (False, True):
            if enabled:
                obs.enable()
                # one journal file per rep: replay/report check the LAST
                # rep's journal against its in-memory result
                jp = root / f"journal_{i}.jsonl"
                journal = jp
            else:
                obs.disable()
                journal = False
            try:
                t0 = time.perf_counter()
                result[enabled] = _submit_cold(
                    root / f"cache_{int(enabled)}", journal, budget)
                best[enabled] = min(best[enabled],
                                    time.perf_counter() - t0)
            finally:
                obs.enable()

    r_off, t_off = result[False], best[False]
    r_on, t_on = result[True], best[True]

    overhead = t_on / t_off
    identical = int(
        r_on.front_metrics.tobytes() == r_off.front_metrics.tobytes()
        and r_on.front_objs.tobytes() == r_off.front_objs.tobytes())

    records = list(obs.read_journal(jp))
    ck = r_on.provenance.cache_key
    rp = obs.replay(records).get(ck, {})
    replay_ok = int(
        rp.get("segments") == r_on.trace.archive_hv.shape[0]
        and rp.get("n_evals") == r_on.provenance.n_evals_run
        and rp.get("final_hv") is not None
        and abs(rp["final_hv"] - float(r_on.trace.archive_hv[-1, 0]))
        <= 1e-9 * max(abs(float(r_on.trace.archive_hv[-1, 0])), 1.0))

    report = render(records)
    seg_rows = [ln for ln in report.splitlines()
                if ln.startswith("  refine")]

    def observed(row):                  # actual_s column is a float, not
        try:                            # the '-' of an unobserved segment
            return float(row.split()[5]) > 0.0
        except ValueError:
            return False

    # the journal holds one plan per journaled rep; each planned segment
    # of each rep must render with an observation
    n_planned = sum(len(p.get("segments", ()))
                    for p in records if p.get("type") == "plan")
    report_ok = int(n_planned > 0 and len(seg_rows) == n_planned
                    and all(observed(r) for r in seg_rows))

    # 3% relative, floored at 50ms absolute: micro-workloads can't fail
    # the gate on scheduler noise alone
    gate = max(1.03 * t_off, t_off + 0.05)
    assert t_on <= gate, (
        f"observability overhead too high: {t_on:.3f}s instrumented vs "
        f"{t_off:.3f}s disabled (gate {gate:.3f}s)")
    assert identical, "fronts differ with observability on vs off"
    assert replay_ok, (
        f"journal replay mismatch: {rp} vs in-memory "
        f"segments={r_on.trace.archive_hv.shape[0]} "
        f"n_evals={r_on.provenance.n_evals_run}")
    assert report_ok, (
        f"report incomplete: {len(seg_rows)} observed rows for "
        f"{n_planned} planned segments")

    return [
        dict(name="obs_disabled_submit", us_per_call=t_off * 1e6,
             derived=""),
        dict(name="obs_journaled_submit", us_per_call=t_on * 1e6,
             derived=f"overhead={overhead:.4f}"),
        dict(name="obs_identical_fronts", us_per_call=0,
             derived=f"identical={identical}"),
        dict(name="obs_journal_replay", us_per_call=0,
             derived=f"replay={replay_ok}"),
        dict(name="obs_report_complete", us_per_call=0,
             derived=f"report={report_ok}"),
    ]
