"""Paper Sec. V-A: validate the analytical model against the systolic-array
simulator (ScaleSim stand-in).  The paper reports <= 9.8% latency error on a
four-chip transformer with 8x8 PE arrays; we sweep matmuls of the same class
and report per-shape + mean error.

The calibration arm closes the loop (ROADMAP direction 5): fit
``t_tile_overhead_ns`` + ``corr_latency`` on the IN-SAMPLE shapes with
``repro.calib`` and evaluate on shapes the fit never saw.  PASS gate (raises
on failure): held-out mean relative latency error must be <= 0.5x the
uncalibrated DEFAULT_TECH error AND under the paper's 9.8% bound."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.constants import DEFAULT_TECH
from repro.core.dataflow import analyze_chiplet
from repro.core.simulator import SystolicConfig, simulate_matmul
from repro.core.workload import matmul

from .common import timed

SHAPES = [(64, 64, 64), (128, 128, 128), (128, 512, 256), (256, 256, 256),
          (512, 512, 128), (512, 64, 512),
          (100, 100, 100), (72, 56, 40), (320, 192, 96)]   # incl. edge folds

# calibration split: fit on the first six shapes, hold out the last three
# (both bandwidth regimes of a held-out shape stay held out — the split is
# by shape, not by (shape, bw) row)
FIT_SHAPES = SHAPES[:6]
HELD_SHAPES = SHAPES[6:]
BWS = (128.0, 16.0)

# PASS gate (see module docstring)
PAPER_BOUND = 0.098
IMPROVEMENT = 0.5


def _analytical(M, N, K, bw=128.0, tech=DEFAULT_TECH):
    # ScaleSim-matched configuration: one 8x8 core, and a chiplet tile equal
    # to one output fold — the simulator has no chiplet buffer, it streams
    # operands from DRAM per fold
    wl = matmul("mm", M, N, K).to_arrays()
    sh = jnp.asarray([8, 8, 1, 1, 1, 1], jnp.int32)
    sp = jnp.asarray([0, 1, 0, 1, 0, 1], jnp.int32)
    od = jnp.asarray([[0, 1, 2, 3, 4, 5, 6, 7]] * 3, jnp.int32)
    ti = jnp.asarray([[8, 8, K] + [1] * 5, [8, 8, K] + [1] * 5], jnp.int32)
    an = analyze_chiplet(wl, sh, sp, od, ti, tech, ext_bw_gbps=bw)
    return float(an["delay_ns"] * jnp.float32(tech.corr_latency))


def _calibration_arm(quick: bool) -> list:
    """Fit on in-sample shapes, evaluate held-out; gate the result."""
    from repro.calib import fit, simulator_sweep

    train = simulator_sweep(shapes=FIT_SHAPES, bws=BWS)
    held = simulator_sweep(shapes=HELD_SHAPES, bws=BWS)
    res = fit(train, free=("t_tile_overhead_ns", "corr_latency"),
              holdout=held, steps=200 if quick else 400, lr=0.05, seed=0)
    before = res.errors["holdout_before"]["mean"]
    after = res.errors["holdout_after"]["mean"]
    bound = min(IMPROVEMENT * before, PAPER_BOUND)
    ok = after <= bound
    rows = [{
        "name": "validation/calibrated_holdout",
        "us_per_call": 0,
        "derived": (f"held_err={after*100:.2f}% (uncal={before*100:.2f}%, "
                    f"gate<={bound*100:.2f}%) "
                    f"t_tile={res.fitted['t_tile_overhead_ns']:.2f}ns "
                    f"corr={res.fitted['corr_latency']:.4f} "
                    f"{'PASS' if ok else 'FAIL'}"),
    }]
    if not ok:
        raise AssertionError(
            f"calibration gate FAILED: held-out mean latency error "
            f"{after*100:.2f}% > {bound*100:.2f}% "
            f"(uncalibrated {before*100:.2f}%, paper bound "
            f"{PAPER_BOUND*100:.1f}%)")
    return rows


def run(quick: bool = True):
    rows = []
    errs = []
    # compute-bound (128 GB/s) and bandwidth-starved (16 GB/s) regimes:
    # the second exposes the granularity difference between the per-fold
    # simulator and the per-pass analytical model
    for bw in BWS:
        for (M, N, K) in SHAPES:
            sim = simulate_matmul(M, N, K, SystolicConfig(8, 8,
                                                          dram_bw_gbps=bw))
            (model_ns), us = timed(_analytical, M, N, K, bw, repeat=1)
            err = abs(model_ns - sim["latency_ns"]) / sim["latency_ns"]
            errs.append(err)
            rows.append({
                "name": f"validation/mm{M}x{N}x{K}@{bw:.0f}GBps",
                "us_per_call": us,
                "derived": f"err={err*100:.1f}% model={model_ns:.0f}ns "
                           f"sim={sim['latency_ns']:.0f}ns",
            })
    rows.append({"name": "validation/mean", "us_per_call": 0,
                 "derived": f"mean_err={np.mean(errs)*100:.1f}% "
                            f"(paper: <=9.8%)"})
    rows += _calibration_arm(quick)
    return rows
