"""Cross-workload transfer benchmark: warm-starting a *held-out* library
graph from the migrated fronts of its nearest cached specs must beat a
cold start — hypervolume-at-budget, exact-spend methodology.

Scenario (``repro.core.presets.workload_library``): the service first
explores two attention-block graphs (qwen2-72b, internlm2-1.8b), then
queries the held-out qwen2.5-32b attention block it has never seen.

Arms (same PRNG key, same pow2 segmenting, ``BudgetPolicy(adaptive=False)``
so every arm spends EXACTLY its budget — the ``bench_explore`` adaptive-arm
methodology):

* ``cold``     — ``transfer=True`` against an EMPTY cache: no neighbor
  exists, so the population is seeded by the ``balanced_init`` fallback and
  spends the FULL budget ``B``.
* ``transfer`` — ``transfer=True`` against the populated cache: the
  population is seeded from the neighbors' migrated fronts and spends only
  ``B/2`` (<= the 60%-of-budget acceptance bound).

Gate: the transferred run's final archive-projected hypervolume must reach
the cold run's, at half its evaluation spend, seeded from >= 1 neighbor.

**Warm-refinement arms** (transfer v2): the held-out graph is first
shallow-explored at ``B/8``, that archive state is cloned, and a
budget-increase refinement resumes it twice at budget ``B`` — unseeded vs
seeded (``transfer=True``, neighbors cached, ``ManifestPolicy`` bounded
at 2 entries so LRU eviction runs live).  Gate: the seeded refinement's
per-segment archive-hypervolume trace must CROSS the unseeded
refinement's final hypervolume within 75% of the evaluations the
unseeded run spent, seeded from >= 1 neighbor, with the manifest inside
its bound and nearest-neighbor queries error-free.

Timings are measured live; all cache directories are wiped up front so
every arm is genuinely cold on disk.
"""

from __future__ import annotations

import shutil
import time

import jax

import repro.core as C
from repro.explore.archive import ManifestPolicy
from repro.explore.nsga import NSGAConfig
from repro.explore.service import BudgetPolicy, ExplorationService

from .common import ARTIFACTS, QUICK

OBJECTIVES = ("latency_ns", "cost_usd")
# bounded space (<= 2x2 core / 1x2 chiplet arrays) so the budgets below can
# actually converge the front — the regime where a head start is measurable
SPACE_KW = dict(max_shape=(8, 8, 2, 2, 1, 2))
CH_MAX = 2
NSGA = NSGAConfig(pop=32, immigrants=0.0, mutations=1)
POLICY = BudgetPolicy(adaptive=False, reallocate=False)
KEY = 42

NEIGHBORS = ("attn_qwen2_72b", "attn_internlm2")
HELD_OUT = "attn_qwen2_5_32b"


def _service(tag: str, wipe: bool = True, **kw) -> ExplorationService:
    d = ARTIFACTS / f"transfer_cache_{tag}"
    if wipe and d.exists():
        shutil.rmtree(d)                     # every arm starts cold on disk
    kw.setdefault("policy", POLICY)
    return ExplorationService(cache_dir=d, nsga=NSGA, **kw)


def _clone(src_tag: str, dst_tag: str):
    src = ARTIFACTS / f"transfer_cache_{src_tag}"
    dst = ARTIFACTS / f"transfer_cache_{dst_tag}"
    if dst.exists():
        shutil.rmtree(dst)
    shutil.copytree(src, dst)


def _explore(svc, graph, budget, transfer=True):
    t0 = time.perf_counter()
    res = svc.explore(graph, OBJECTIVES, budget=budget, ch_max=CH_MAX,
                      space_kwargs=SPACE_KW, transfer=transfer,
                      key=jax.random.PRNGKey(KEY))
    return res, time.perf_counter() - t0


def run(quick: bool = True):
    lib = C.presets.workload_library()
    budget = 1024 if QUICK else 4096         # pow2 x pop => exact spends

    # --- cold arm: empty cache, balanced_init fallback, full budget -------
    svc_cold = _service("cold")
    cold, t_cold = _explore(svc_cold, lib[HELD_OUT], budget)
    assert not cold.from_cache and cold.transferred_from == ()
    assert cold.n_transfer_seeds >= 1        # the balanced_init seed
    hv_cold = float(cold.trace.archive_hv[-1, 0])

    # --- transfer arm: neighbors cached first, half budget ----------------
    svc = _service("warm")
    t_pop = 0.0
    for name in NEIGHBORS:
        _, dt = _explore(svc, lib[name], budget)
        t_pop += dt
    warm, t_warm = _explore(svc, lib[HELD_OUT], budget // 2)
    assert not warm.from_cache
    hv_warm = float(warm.trace.archive_hv[-1, 0])

    ev_frac = warm.n_evals_run / max(cold.n_evals_run, 1)
    ok = (hv_warm >= hv_cold and ev_frac <= 0.60
          and len(warm.transferred_from) >= 1)
    # the acceptance gate is ASSERTED, not just printed — a transfer
    # regression must fail the CI smoke, not merely annotate a CSV row
    assert ok, (f"transfer gate failed: hv_warm={hv_warm:.6g} vs "
                f"hv_cold={hv_cold:.6g}, evals_frac={ev_frac:.2f}, "
                f"neighbors={len(warm.transferred_from)}")

    # --- warm-refinement arms (transfer v2) -------------------------------
    # shallow-explore the held-out graph once, clone the archive state,
    # then resume it twice with the SAME budget: unseeded vs
    # transfer-seeded (neighbors cached, ``ManifestPolicy`` bounded BELOW
    # the number of cached problems so LRU eviction runs live inside the
    # measured path).  The gate reads the seeded run's per-segment
    # archive-hypervolume trace: it must CROSS the unseeded run's final
    # hypervolume within 75% of the evaluations the unseeded run spent.
    rpolicy = BudgetPolicy(adaptive=False, reallocate=False,
                           chunk_generations=4)     # finer crossing trace
    svc_base = _service("refine_base", policy=rpolicy)
    _, t_pre = _explore(svc_base, lib[HELD_OUT], budget // 8,
                        transfer=False)
    _clone("refine_base", "refine_cold")
    _clone("refine_base", "refine_warm")

    svc_rc = _service("refine_cold", wipe=False, policy=rpolicy)
    ref_cold, t_rc = _explore(svc_rc, lib[HELD_OUT], budget,
                              transfer=False)
    assert not ref_cold.from_cache
    hv_rc = float(ref_cold.trace.archive_hv[-1, 0])

    svc_rw = _service("refine_warm", wipe=False, policy=rpolicy,
                      manifest_policy=ManifestPolicy(max_entries=2))
    t_rpop = 0.0
    for name in NEIGHBORS:
        _, dt = _explore(svc_rw, lib[name], budget, transfer=False)
        t_rpop += dt
    ref_warm, t_rw = _explore(svc_rw, lib[HELD_OUT], budget)
    assert not ref_warm.from_cache
    hv_rw = float(ref_warm.trace.archive_hv[-1, 0])

    # the bounded manifest held, and nearest-neighbor queries stay clean
    assert len(svc_rw.manifest) <= 2
    probe = next(iter(svc_rw.manifest.entries.values()))["embedding"]
    assert len(svc_rw.manifest.nearest(probe, k=8)) >= 1

    rows = ref_warm.trace.archive_hv[:, 0]
    seg = ref_warm.n_evals_run // max(len(rows), 1)
    cross = next((int((i + 1) * seg) for i, v in enumerate(rows)
                  if v >= hv_rc), None)
    ev_frac_ref = (cross / max(ref_cold.n_evals_run, 1)
                   if cross is not None else float("inf"))
    ok_ref = (hv_rw >= hv_rc and ev_frac_ref <= 0.75
              and len(ref_warm.transferred_from) >= 1)
    assert ok_ref, (
        f"warm-refinement gate failed: hv_seeded={hv_rw:.6g} vs "
        f"hv_unseeded={hv_rc:.6g}, evals_to_reach_frac={ev_frac_ref:.2f}, "
        f"neighbors={len(ref_warm.transferred_from)}")
    return [
        {"name": "transfer/neighbor_populate", "us_per_call": t_pop * 1e6,
         "derived": f"graphs={len(NEIGHBORS)} budget={budget}"},
        {"name": "transfer/cold_arm", "us_per_call": t_cold * 1e6,
         "derived": (f"evals={cold.n_evals_run} hv={hv_cold:.6g} "
                     f"seeds={cold.n_transfer_seeds} (balanced_init)")},
        {"name": "transfer/warm_arm", "us_per_call": t_warm * 1e6,
         "derived": (f"evals={warm.n_evals_run} hv={hv_warm:.6g} "
                     f"seeds={warm.n_transfer_seeds} "
                     f"neighbors={len(warm.transferred_from)}")},
        {"name": "transfer/gate", "us_per_call": 0,
         "derived": (f"hv_ratio={hv_warm / max(hv_cold, 1e-12):.4f} "
                     f"evals_frac={ev_frac:.2f} "
                     f"({'PASS' if ok else 'FAIL'} hv>=cold & <=0.60 "
                     f"& >=1 neighbor)")},
        {"name": "transfer/refine_pre", "us_per_call": t_pre * 1e6,
         "derived": f"shallow-explore budget={budget // 8}"},
        {"name": "transfer/refine_unseeded", "us_per_call": t_rc * 1e6,
         "derived": f"evals={ref_cold.n_evals_run} hv={hv_rc:.6g}"},
        {"name": "transfer/refine_seeded", "us_per_call": t_rw * 1e6,
         "derived": (f"evals={ref_warm.n_evals_run} hv={hv_rw:.6g} "
                     f"seeds={ref_warm.n_transfer_seeds} "
                     f"neighbors={len(ref_warm.transferred_from)} "
                     f"manifest={len(svc_rw.manifest)}<=2")},
        {"name": "transfer/refine_gate", "us_per_call": 0,
         "derived": (f"hv_ratio={hv_rw / max(hv_rc, 1e-12):.4f} "
                     f"evals_to_reach_frac={ev_frac_ref:.2f} "
                     f"({'PASS' if ok_ref else 'FAIL'} hv>=unseeded "
                     f"& crosses <=0.75 & >=1 neighbor "
                     f"& bounded manifest)")},
    ]
